//! SOAR-analog backbone (Sun et al. 2023): IVF with *spilled orthogonal*
//! redundant assignments.
//!
//! Every key is stored in its primary (nearest-centroid) cell and in one
//! secondary cell chosen to best cover the primary residual: among the
//! next-best centroids, pick the one whose direction is most aligned with
//! the residual `key - c_primary`. When quantization error in the primary
//! cell would make the key invisible to a query, the secondary assignment
//! catches it — fewer probes reach the same recall.

use std::io::Read;

use anyhow::{ensure, Result};

use crate::api::Effort;
use crate::index::artifact;
use crate::index::ivf::{invert_to_probers, rank_cells_tensor};
use crate::index::kmeans::KMeans;
use crate::index::spec::{IndexSpec, SoarSpec};
use crate::index::traits::{SearchCost, SearchResult, TopK, VectorIndex};
use crate::tensor::{dot, Tensor};

pub struct SoarIndex {
    nlist: usize,
    d: usize,
    centroids: Tensor,
    packed: Tensor, // [slots, d] — n * 2 slots (primary + spill)
    ids: Vec<u32>,
    offsets: Vec<usize>,
    n_keys: usize,
    /// Runner-up centroids considered per spill (spec echo).
    spill: usize,
}

impl SoarIndex {
    /// `spill_candidates`: how many runner-up centroids to consider for
    /// the secondary assignment.
    pub fn build(keys: &Tensor, nlist: usize, spill_candidates: usize, seed: u64) -> SoarIndex {
        let n = keys.rows();
        let d = keys.row_width();
        let km = KMeans::fit(keys, nlist, 15, seed);

        // choose secondary cell per key
        let mut assignments: Vec<(u32, u32)> = Vec::with_capacity(n);
        let cand = spill_candidates.clamp(1, nlist.saturating_sub(1).max(1));
        for i in 0..n {
            let xi = keys.row(i);
            let primary = km.assign[i];
            // rank all centroids by score, take runner-ups
            let mut top = TopK::new(cand + 1);
            for j in 0..nlist {
                top.push(dot(xi, km.centroids.row(j)), j as u32);
            }
            let (ranked, _) = top.into_sorted();
            // residual to primary centroid
            let cp = km.centroids.row(primary as usize);
            let resid: Vec<f32> = xi.iter().zip(cp).map(|(a, b)| a - b).collect();
            let rn = dot(&resid, &resid).sqrt().max(1e-9);
            let mut best = (primary, f32::NEG_INFINITY);
            for &j in ranked.iter() {
                if j == primary {
                    continue;
                }
                // alignment of candidate centroid with the residual
                let align = dot(&resid, km.centroids.row(j as usize)) / rn;
                if align > best.1 {
                    best = (j, align);
                }
            }
            assignments.push((primary, best.0));
        }

        // pack both assignments contiguously by cell
        let mut counts = vec![0usize; nlist];
        for &(p, s) in &assignments {
            counts[p as usize] += 1;
            if s != p {
                counts[s as usize] += 1;
            }
        }
        let mut offsets = vec![0usize; nlist + 1];
        for j in 0..nlist {
            offsets[j + 1] = offsets[j] + counts[j];
        }
        let slots = offsets[nlist];
        let mut cursor = offsets.clone();
        let mut packed = Tensor::zeros(&[slots, d]);
        let mut ids = vec![0u32; slots];
        for (i, &(p, s)) in assignments.iter().enumerate() {
            for cell in [p, s] {
                if cell == s && s == p {
                    continue;
                }
                let pos = cursor[cell as usize];
                cursor[cell as usize] += 1;
                packed.row_mut(pos).copy_from_slice(keys.row(i));
                ids[pos] = i as u32;
            }
        }

        SoarIndex {
            nlist,
            d,
            centroids: km.centroids,
            packed,
            ids,
            offsets,
            n_keys: n,
            spill: spill_candidates,
        }
    }

    /// Total stored slots (n + spills); storage overhead diagnostic.
    pub fn slots(&self) -> usize {
        self.ids.len()
    }

    /// Deserialize from an artifact payload (see [`crate::index::artifact`]).
    pub(crate) fn read_payload(r: &mut dyn Read) -> Result<SoarIndex> {
        let centroids = artifact::r_tensor(r)?;
        let packed = artifact::r_tensor(r)?;
        let ids = artifact::r_u32s(r)?;
        let offsets = artifact::r_usizes(r)?;
        let n_keys = artifact::r_u64(r)? as usize;
        let spill = artifact::r_u64(r)? as usize;
        let nlist = centroids.rows();
        let d = packed.row_width();
        ensure!(
            nlist >= 1
                && centroids.row_width() == d
                && packed.rows() == ids.len()
                && offsets.len() == nlist + 1
                && offsets.last().copied() == Some(ids.len())
                && offsets.windows(2).all(|w| w[0] <= w[1])
                && n_keys <= ids.len()
                && ids.iter().all(|&id| (id as usize) < n_keys),
            "inconsistent SOAR payload: {} cells, {} slots, {} keys, {} offsets",
            nlist,
            ids.len(),
            n_keys,
            offsets.len()
        );
        Ok(SoarIndex {
            nlist,
            d,
            centroids,
            packed,
            ids,
            offsets,
            n_keys,
            spill,
        })
    }
}

impl SoarIndex {
    fn search_probes(&self, query: &[f32], k: usize, nprobe: usize) -> SearchResult {
        let nprobe = nprobe.clamp(1, self.nlist);
        let mut cell_top = TopK::new(nprobe);
        for j in 0..self.nlist {
            cell_top.offer(dot(query, self.centroids.row(j)), j as u32);
        }
        let (cells, _) = cell_top.into_sorted();
        // dedup across spilled copies: TopK tie-break keeps one entry per
        // id only if we guard — use a seen-set sized to keys.
        let mut top = TopK::new(k);
        let mut scanned = 0u64;
        let mut seen = vec![false; self.n_keys];
        for &cell in &cells {
            let (s, e) = (self.offsets[cell as usize], self.offsets[cell as usize + 1]);
            for pos in s..e {
                let id = self.ids[pos];
                if seen[id as usize] {
                    continue;
                }
                seen[id as usize] = true;
                top.offer(dot(query, self.packed.row(pos)), id);
                scanned += 1;
            }
        }
        let (ids, scores) = top.into_sorted();
        SearchResult {
            ids,
            scores,
            cost: SearchCost {
                flops: (self.nlist as u64 + scanned) * self.d as u64 * 2,
                keys_scanned: scanned,
                cells_probed: nprobe as u64,
            },
        }
    }
}

impl VectorIndex for SoarIndex {
    fn name(&self) -> &str {
        "soar"
    }

    fn len(&self) -> usize {
        self.n_keys
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn n_cells(&self) -> usize {
        self.nlist
    }

    fn search_effort(&self, query: &[f32], k: usize, effort: Effort) -> SearchResult {
        self.search_probes(query, k, effort.resolve(self.nlist))
    }

    /// Fused batched probe: batch × centroids as one gemm tile, then a
    /// grouped cell scan streaming each probed cell once for every query
    /// probing it, with a per-query bitmap deduplicating spilled copies.
    /// Both copies of a key hold identical vector data, so which copy a
    /// query scores first cannot change its result — per-query results
    /// and scan counts are bit-identical to
    /// [`SoarIndex::search_effort`].
    fn search_batch_effort(&self, queries: &Tensor, k: usize, effort: Effort) -> Vec<SearchResult> {
        let b = queries.rows();
        if b == 0 {
            return Vec::new();
        }
        let nprobe = effort.resolve(self.nlist).clamp(1, self.nlist);
        let cells = rank_cells_tensor(queries, &self.centroids, nprobe);
        let probers = invert_to_probers(&cells, self.nlist);
        let mut tops: Vec<TopK> = (0..b).map(|_| TopK::new(k)).collect();
        let mut scanned = vec![0u64; b];
        // per-query seen bitmap over key ids: 1 bit per (query, key) —
        // 64x smaller than the per-query path's bool vec would be if
        // replicated, and reset-free because each query's stripe is
        // touched only within this call
        let words = self.n_keys.div_ceil(64);
        let mut seen = vec![0u64; b * words];
        for (cell, qs) in probers.iter().enumerate() {
            if qs.is_empty() {
                continue;
            }
            let (s, e) = (self.offsets[cell], self.offsets[cell + 1]);
            for pos in s..e {
                let id = self.ids[pos] as usize;
                let key = self.packed.row(pos);
                let (word, bit) = (id >> 6, 1u64 << (id & 63));
                for &q in qs {
                    let q = q as usize;
                    let w = &mut seen[q * words + word];
                    if *w & bit != 0 {
                        continue;
                    }
                    *w |= bit;
                    tops[q].offer(dot(queries.row(q), key), self.ids[pos]);
                    scanned[q] += 1;
                }
            }
        }
        tops.into_iter()
            .zip(scanned)
            .map(|(top, scanned)| {
                let (ids, scores) = top.into_sorted();
                SearchResult {
                    ids,
                    scores,
                    cost: SearchCost {
                        flops: (self.nlist as u64 + scanned) * self.d as u64 * 2,
                        keys_scanned: scanned,
                        cells_probed: nprobe as u64,
                    },
                }
            })
            .collect()
    }

    fn spec(&self) -> IndexSpec {
        IndexSpec::Soar(SoarSpec {
            nlist: self.nlist,
            spill: self.spill,
        })
    }

    fn write_payload(&self, w: &mut Vec<u8>) -> Result<()> {
        artifact::w_tensor(w, &self.centroids)?;
        artifact::w_tensor(w, &self.packed)?;
        artifact::w_u32s(w, &self.ids)?;
        artifact::w_usizes(w, &self.offsets)?;
        artifact::w_u64(w, self.n_keys as u64)?;
        artifact::w_u64(w, self.spill as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::flat::FlatIndex;
    use crate::index::ivf::IvfIndex;
    use crate::tensor::normalize_rows;
    use crate::util::Rng;

    fn unit_keys(n: usize, d: usize, seed: u64) -> Tensor {
        let mut t = Tensor::zeros(&[n, d]);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        normalize_rows(&mut t);
        t
    }

    #[test]
    fn storage_has_spills() {
        let keys = unit_keys(300, 16, 1);
        let soar = SoarIndex::build(&keys, 8, 4, 2);
        assert!(soar.slots() > 300, "expected redundant assignments");
        assert!(soar.slots() <= 600);
    }

    #[test]
    fn full_probe_matches_flat() {
        let keys = unit_keys(300, 16, 3);
        let soar = SoarIndex::build(&keys, 8, 4, 4);
        let flat = FlatIndex::new(keys.clone());
        let q = unit_keys(10, 16, 5);
        for i in 0..10 {
            let a = soar.search_effort(q.row(i), 3, Effort::Exhaustive);
            let b = flat.search_effort(q.row(i), 3, Effort::Exhaustive);
            assert_eq!(a.ids, b.ids, "query {i}");
        }
    }

    #[test]
    fn no_duplicate_results() {
        let keys = unit_keys(200, 8, 6);
        let soar = SoarIndex::build(&keys, 6, 3, 7);
        let q = unit_keys(1, 8, 8);
        let res = soar.search_effort(q.row(0), 20, Effort::Probes(4));
        let mut ids = res.ids.clone();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), res.ids.len());
    }

    #[test]
    fn batched_search_is_bit_identical_to_per_query() {
        let keys = unit_keys(260, 12, 12);
        let soar = SoarIndex::build(&keys, 7, 3, 13);
        let q = unit_keys(8, 12, 14);
        for effort in [Effort::Probes(1), Effort::Probes(3), Effort::Exhaustive] {
            let batched = soar.search_batch_effort(&q, 5, effort);
            for i in 0..8 {
                let single = soar.search_effort(q.row(i), 5, effort);
                assert_eq!(batched[i].ids, single.ids, "{effort:?} query {i}");
                assert_eq!(batched[i].scores, single.scores, "{effort:?} query {i}");
                assert_eq!(batched[i].cost, single.cost, "{effort:?} query {i}");
            }
        }
    }

    #[test]
    fn low_probe_recall_at_least_ivf() {
        // The whole point of SOAR: better recall at small nprobe. Compare
        // aggregate recall@1 over many queries vs plain IVF with the same
        // cell count and seed.
        let keys = unit_keys(800, 16, 9);
        let soar = SoarIndex::build(&keys, 16, 6, 10);
        let ivf = IvfIndex::build(&keys, 16, 15, 10);
        let flat = FlatIndex::new(keys.clone());
        let q = unit_keys(80, 16, 11);
        let (mut hs, mut hi) = (0, 0);
        for i in 0..80 {
            let truth = flat.search_effort(q.row(i), 1, Effort::Exhaustive).ids[0];
            let sp = soar.search_effort(q.row(i), 1, Effort::Probes(2));
            if sp.ids.first() == Some(&truth) {
                hs += 1;
            }
            let ip = ivf.search_effort(q.row(i), 1, Effort::Probes(2));
            if ip.ids.first() == Some(&truth) {
                hi += 1;
            }
        }
        assert!(hs + 3 >= hi, "soar {hs} vs ivf {hi}");
    }
}
