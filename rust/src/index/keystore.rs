//! Key-matrix storage for the exact-scoring backbones: full-precision
//! f32 rows (the default) or compact binary16 rows (`storage=f16`),
//! which halve scan-path memory bandwidth at ~2⁻¹¹ relative rounding
//! error per stored coordinate.
//!
//! Scoring goes through the dispatched kernels
//! ([`crate::tensor::kernels::dot`] / [`dot_f16`]), so the per-query
//! and batched scan paths of an index share one kernel per (query, key)
//! pair and stay bit-identical to each other regardless of storage.
//!
//! [`dot_f16`]: crate::tensor::kernels::dot_f16

use anyhow::{bail, ensure, Result};

use crate::index::artifact::{self, Src};
use crate::tensor::half::{decode_f16, encode_f16};
use crate::tensor::mapped::Section;
use crate::tensor::{gemm_nt_tile, kernels, Tensor};

/// Key-matrix precision knob (`storage=` in flat/leanvec specs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Storage {
    /// Full-precision f32 rows — bit-identical to the pre-knob behavior.
    #[default]
    F32,
    /// binary16 rows, dequantized inside the scoring kernel.
    F16,
}

impl Storage {
    pub fn name(self) -> &'static str {
        match self {
            Storage::F32 => "f32",
            Storage::F16 => "f16",
        }
    }
}

impl std::fmt::Display for Storage {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Storage {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Storage> {
        match s {
            "f32" => Ok(Storage::F32),
            "f16" => Ok(Storage::F16),
            other => bail!("unknown storage '{other}' (expected f32 or f16)"),
        }
    }
}

/// A key matrix in its selected storage precision. Both arms hold
/// their rows in a [`Section`]-backed container, so on the zero-copy
/// artifact read paths the scan kernels pull key bytes straight from
/// the mapped file instead of a decoded copy.
pub enum KeyStore {
    F32(Tensor),
    F16 {
        n: usize,
        d: usize,
        rows: Section<u16>,
    },
}

impl KeyStore {
    /// Encode `keys` (`[n, d]`) into the requested storage. `F32` keeps
    /// the tensor untouched (bit-identical scores); `F16` rounds each
    /// coordinate to nearest-even binary16 once, at build time.
    pub fn new(keys: Tensor, storage: Storage) -> KeyStore {
        match storage {
            Storage::F32 => KeyStore::F32(keys),
            Storage::F16 => KeyStore::F16 {
                n: keys.rows(),
                d: keys.row_width(),
                rows: Section::owned(encode_f16(keys.data())),
            },
        }
    }

    pub fn storage(&self) -> Storage {
        match self {
            KeyStore::F32(_) => Storage::F32,
            KeyStore::F16 { .. } => Storage::F16,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            KeyStore::F32(t) => t.rows(),
            KeyStore::F16 { n, .. } => *n,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dim(&self) -> usize {
        match self {
            KeyStore::F32(t) => t.row_width(),
            KeyStore::F16 { d, .. } => *d,
        }
    }

    /// The underlying f32 tensor. Panics for f16 storage — callers that
    /// need raw rows regardless of storage should use [`to_tensor`]
    /// (which decodes) or [`score`] (which never materializes rows).
    ///
    /// [`to_tensor`]: KeyStore::to_tensor
    /// [`score`]: KeyStore::score
    pub fn as_f32(&self) -> &Tensor {
        match self {
            KeyStore::F32(t) => t,
            KeyStore::F16 { .. } => {
                panic!("KeyStore::as_f32 on f16 storage (use to_tensor/score)")
            }
        }
    }

    /// Decode to a dense f32 tensor (copies; exact for f32 storage,
    /// the stored — already rounded — values for f16).
    pub fn to_tensor(&self) -> Tensor {
        match self {
            KeyStore::F32(t) => t.clone(),
            KeyStore::F16 { n, d, rows } => {
                Tensor::from_vec(&[*n, *d], decode_f16(rows.as_slice()))
            }
        }
    }

    /// Whether the stored rows are a borrowed view of a mapped
    /// container (zero-copy) rather than an owned RAM buffer.
    pub fn is_view(&self) -> bool {
        match self {
            KeyStore::F32(t) => t.is_view(),
            KeyStore::F16 { rows, .. } => rows.is_view(),
        }
    }

    /// Sequential-scan `madvise` hint for view-backed rows (no-op when
    /// owned).
    pub fn advise_sequential(&self) {
        match self {
            KeyStore::F32(t) => t.advise_sequential(),
            KeyStore::F16 { rows, .. } => rows.advise_sequential(),
        }
    }

    /// Inner product of `query` with stored row `id`, through the
    /// dispatched kernel for this storage.
    #[inline]
    pub fn score(&self, query: &[f32], id: usize) -> f32 {
        match self {
            KeyStore::F32(t) => kernels::dot(query, t.row(id)),
            KeyStore::F16 { d, rows, .. } => {
                kernels::dot_f16(query, &rows[id * d..(id + 1) * d])
            }
        }
    }

    /// Score a tile: `out[i * (j1 - j0) + (j - j0)] = <a_i, key_j>` for
    /// `a` holding `m` rows of width `dim()`. The f32 arm runs the
    /// fused [`gemm_nt_tile`] kernel; the f16 arm scores row-by-row
    /// through the same [`kernels::dot_f16`] as [`score`], so both arms
    /// stay bit-identical to their per-query path.
    ///
    /// [`score`]: KeyStore::score
    pub fn scan_tile(&self, a: &[f32], m: usize, j0: usize, j1: usize, out: &mut [f32]) {
        let d = self.dim();
        let w = j1 - j0;
        debug_assert_eq!(a.len(), m * d);
        debug_assert!(out.len() >= m * w);
        match self {
            KeyStore::F32(t) => {
                gemm_nt_tile(a, &t.data()[j0 * d..j1 * d], d, &mut out[..m * w]);
            }
            KeyStore::F16 { rows, .. } => {
                for i in 0..m {
                    let q = &a[i * d..(i + 1) * d];
                    for j in j0..j1 {
                        out[i * w + (j - j0)] = kernels::dot_f16(q, &rows[j * d..(j + 1) * d]);
                    }
                }
            }
        }
    }

    /// Serialize: a storage tag, then the payload for that storage, in
    /// the current (aligned v3) layout — the row matrix lands in a
    /// 64-byte-aligned section so readers can serve it in place.
    pub fn write_payload(&self, w: &mut Vec<u8>) -> Result<()> {
        match self {
            KeyStore::F32(t) => {
                artifact::w_u32(w, 0)?;
                artifact::w_tensor_v3(w, t)
            }
            KeyStore::F16 { n, d, rows } => {
                artifact::w_u32(w, 1)?;
                artifact::w_u64(w, *n as u64)?;
                artifact::w_u64(w, *d as u64)?;
                artifact::w_section_u16s(w, rows.as_slice())
            }
        }
    }

    /// Deserialize a tagged key store. `version` is the artifact
    /// version: ≥ 3 reads the aligned zero-copy layout (rows become
    /// borrowed views when the source is a real mapping), 2 the legacy
    /// unaligned one. Version-1 payloads have no tag — their readers
    /// call `artifact::r_tensor` directly and wrap it in
    /// `KeyStore::F32`.
    pub fn read_payload(src: &mut Src, version: u32) -> Result<KeyStore> {
        match artifact::r_u32(&mut *src)? {
            0 => {
                let t = if version >= 3 {
                    artifact::r_tensor_v3(src)?
                } else {
                    artifact::r_tensor(&mut *src)?
                };
                Ok(KeyStore::F32(t))
            }
            1 => {
                let n = artifact::r_u64(&mut *src)? as usize;
                let d = artifact::r_u64(&mut *src)? as usize;
                let rows = if version >= 3 {
                    artifact::r_section::<u16>(src)?
                } else {
                    Section::owned(artifact::r_u16s(&mut *src)?)
                };
                ensure!(
                    n.checked_mul(d).is_some_and(|e| e == rows.len()),
                    "f16 key store advertises {n}x{d} but holds {} halves",
                    rows.len()
                );
                Ok(KeyStore::F16 { n, d, rows })
            }
            other => bail!("unknown key-store storage tag {other} in artifact"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        t
    }

    #[test]
    fn f32_store_is_transparent() {
        let keys = randt(&[20, 16], 1);
        let ks = KeyStore::new(keys.clone(), Storage::F32);
        assert_eq!(ks.storage(), Storage::F32);
        assert_eq!((ks.len(), ks.dim()), (20, 16));
        let q = randt(&[1, 16], 2);
        for i in 0..20 {
            assert_eq!(
                ks.score(q.row(0), i).to_bits(),
                crate::tensor::dot(q.row(0), keys.row(i)).to_bits()
            );
        }
        assert_eq!(ks.to_tensor().data(), keys.data());
        assert_eq!(ks.as_f32().data(), keys.data());
    }

    #[test]
    fn f16_store_scores_close_and_self_consistently() {
        let keys = randt(&[30, 24], 3);
        let ks = KeyStore::new(keys.clone(), Storage::F16);
        assert_eq!(ks.storage(), Storage::F16);
        let q = randt(&[1, 24], 4);
        let decoded = ks.to_tensor();
        for i in 0..30 {
            let s = ks.score(q.row(0), i);
            let exact = crate::tensor::dot(q.row(0), keys.row(i));
            // storage rounding only: ~2^-11 per coordinate
            assert!((s - exact).abs() <= 2e-2 * (1.0 + exact.abs()), "row {i}");
            // scoring the decoded tensor must agree within kernel tolerance
            let dec = crate::tensor::dot(q.row(0), decoded.row(i));
            assert!((s - dec).abs() <= 1e-4, "row {i}: {s} vs {dec}");
        }
    }

    #[test]
    fn scan_tile_matches_score_bitwise() {
        for storage in [Storage::F32, Storage::F16] {
            let keys = randt(&[37, 16], 5);
            let ks = KeyStore::new(keys, storage);
            let q = randt(&[3, 16], 6);
            let (j0, j1) = (8, 37);
            let mut out = vec![0.0f32; 3 * (j1 - j0)];
            ks.scan_tile(q.data(), 3, j0, j1, &mut out);
            for i in 0..3 {
                for j in j0..j1 {
                    let got = out[i * (j1 - j0) + (j - j0)];
                    let want = ks.score(q.row(i), j);
                    assert_eq!(got.to_bits(), want.to_bits(), "{storage:?} q{i} k{j}");
                }
            }
        }
    }

    #[test]
    fn payload_round_trips_bitwise() {
        for storage in [Storage::F32, Storage::F16] {
            let ks = KeyStore::new(randt(&[11, 8], 7), storage);
            let mut buf = Vec::new();
            ks.write_payload(&mut buf).unwrap();
            let back = KeyStore::read_payload(&mut Src::new(&buf), artifact::VERSION).unwrap();
            assert_eq!(back.storage(), storage);
            assert_eq!((back.len(), back.dim()), (11, 8));
            assert_eq!(back.to_tensor().data(), ks.to_tensor().data());
            assert!(!back.is_view()); // no backing map on this path
        }
        // corrupt tag
        let mut buf = Vec::new();
        artifact::w_u32(&mut buf, 9).unwrap();
        assert!(KeyStore::read_payload(&mut Src::new(&buf), artifact::VERSION).is_err());
    }

    #[test]
    fn storage_knob_parses_and_prints() {
        assert_eq!("f16".parse::<Storage>().unwrap(), Storage::F16);
        assert_eq!("f32".parse::<Storage>().unwrap(), Storage::F32);
        assert!("f64".parse::<Storage>().is_err());
        assert_eq!(Storage::F16.to_string(), "f16");
        assert_eq!(Storage::default(), Storage::F32);
    }
}
