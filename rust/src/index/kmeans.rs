//! Spherical k-means: the coarse quantizer for every IVF-family backbone
//! and the database partitioner for the routing experiments (Sec. 4.3).
//!
//! k-means++ seeding, Lloyd iterations with centroid renormalization
//! (inner-product assignment on unit-norm data == cosine k-means), empty
//! clusters re-seeded from the farthest points. `fit_best_balance` runs
//! several restarts and keeps the most size-balanced clustering, exactly
//! as the paper does ("select the clustering which yields the most even
//! cluster sizes").

use crate::tensor::{dot, normalize_rows, Tensor};
use crate::util::threads::parallel_chunks;
use crate::util::Rng;
use std::sync::atomic::{AtomicU32, Ordering};

/// Fitted clustering.
pub struct KMeans {
    pub centroids: Tensor, // [c, d]
    pub assign: Vec<u32>,  // [n]
    pub sizes: Vec<usize>, // [c]
}

impl KMeans {
    /// Lloyd's algorithm with k-means++ init on inner-product similarity.
    pub fn fit(x: &Tensor, c: usize, iters: usize, seed: u64) -> KMeans {
        let n = x.rows();
        let d = x.row_width();
        assert!(c >= 1 && c <= n, "c={c} n={n}");
        let mut rng = Rng::new(seed);

        // --- k-means++ seeding (distance = 2 - 2<x, c> on unit sphere) --
        let mut centroids = Tensor::zeros(&[c, d]);
        let first = rng.below(n);
        centroids.row_mut(0).copy_from_slice(x.row(first));
        let mut d2 = vec![f32::MAX; n];
        for ci in 1..c {
            let prev = centroids.row(ci - 1).to_vec();
            let mut total = 0.0f64;
            for i in 0..n {
                let dist = (2.0 - 2.0 * dot(x.row(i), &prev)).max(0.0);
                if dist < d2[i] {
                    d2[i] = dist;
                }
                total += d2[i] as f64;
            }
            let mut r = rng.uniform() * total;
            let mut pick = n - 1;
            for i in 0..n {
                r -= d2[i] as f64;
                if r <= 0.0 {
                    pick = i;
                    break;
                }
            }
            centroids.row_mut(ci).copy_from_slice(x.row(pick));
        }

        // --- Lloyd iterations --------------------------------------------
        let mut assign = vec![0u32; n];
        for _ in 0..iters {
            // assignment (parallel)
            let assign_atomic: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
            parallel_chunks(n, 256, |_, i0, i1| {
                for i in i0..i1 {
                    let xi = x.row(i);
                    let mut best = (0u32, f32::NEG_INFINITY);
                    for j in 0..c {
                        let s = dot(xi, centroids.row(j));
                        if s > best.1 {
                            best = (j as u32, s);
                        }
                    }
                    assign_atomic[i].store(best.0, Ordering::Relaxed);
                }
            });
            let new_assign: Vec<u32> = assign_atomic.iter().map(|a| a.load(Ordering::Relaxed)).collect();
            let changed = new_assign
                .iter()
                .zip(&assign)
                .filter(|(a, b)| a != b)
                .count();
            assign = new_assign;

            // update
            let mut sums = Tensor::zeros(&[c, d]);
            let mut counts = vec![0usize; c];
            for i in 0..n {
                let j = assign[i] as usize;
                counts[j] += 1;
                let row = sums.row_mut(j);
                for (a, b) in row.iter_mut().zip(x.row(i)) {
                    *a += b;
                }
            }
            for j in 0..c {
                if counts[j] == 0 {
                    // re-seed an empty cluster from a random point
                    let pick = rng.below(n);
                    sums.row_mut(j).copy_from_slice(x.row(pick));
                    counts[j] = 1;
                }
            }
            centroids = sums;
            normalize_rows(&mut centroids);

            if changed == 0 {
                break;
            }
        }

        let mut sizes = vec![0usize; c];
        for &a in &assign {
            sizes[a as usize] += 1;
        }
        KMeans {
            centroids,
            assign,
            sizes,
        }
    }

    /// Balance metric in [0,1]: 1 = perfectly even sizes.
    pub fn balance(&self) -> f64 {
        let n: usize = self.sizes.iter().sum();
        let c = self.sizes.len();
        if n == 0 || c == 0 {
            return 0.0;
        }
        let ideal = n as f64 / c as f64;
        let mad = self
            .sizes
            .iter()
            .map(|&s| (s as f64 - ideal).abs())
            .sum::<f64>()
            / c as f64;
        (1.0 - mad / ideal).max(0.0)
    }

    /// Run `restarts` independent fits; keep the most size-balanced one
    /// (paper Sec. 4.3).
    pub fn fit_best_balance(x: &Tensor, c: usize, iters: usize, restarts: usize, seed: u64) -> KMeans {
        let mut best: Option<KMeans> = None;
        for r in 0..restarts.max(1) {
            let km = Self::fit(x, c, iters, seed.wrapping_add(r as u64 * 0x9E37));
            if best.as_ref().map_or(true, |b| km.balance() > b.balance()) {
                best = Some(km);
            }
        }
        best.unwrap()
    }

    /// Inverted lists: cluster -> member key ids.
    pub fn inverted_lists(&self) -> Vec<Vec<u32>> {
        let mut lists = vec![Vec::new(); self.centroids.rows()];
        for (i, &a) in self.assign.iter().enumerate() {
            lists[a as usize].push(i as u32);
        }
        lists
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three well-separated directions on the sphere.
    fn clustered_data(n_per: usize, seed: u64) -> Tensor {
        let d = 16;
        let mut rng = Rng::new(seed);
        let mut centers = Tensor::zeros(&[3, d]);
        centers.row_mut(0)[0] = 1.0;
        centers.row_mut(1)[5] = 1.0;
        centers.row_mut(2)[11] = 1.0;
        let mut x = Tensor::zeros(&[3 * n_per, d]);
        for i in 0..3 * n_per {
            let c = i % 3;
            let row = x.row_mut(i);
            row.copy_from_slice(centers.row(c));
            for v in row.iter_mut() {
                *v += rng.normal() as f32 * 0.05;
            }
        }
        normalize_rows(&mut x);
        x
    }

    #[test]
    fn recovers_separated_clusters() {
        let x = clustered_data(60, 1);
        let km = KMeans::fit(&x, 3, 20, 2);
        // members generated from the same center must share a label
        for base in 0..3 {
            let label = km.assign[base];
            for i in 0..60 {
                assert_eq!(km.assign[base + 3 * i], label, "i={i}");
            }
        }
        assert!(km.balance() > 0.95);
    }

    #[test]
    fn centroids_unit_norm() {
        let x = clustered_data(40, 3);
        let km = KMeans::fit(&x, 3, 10, 4);
        for j in 0..3 {
            let n = dot(km.centroids.row(j), km.centroids.row(j)).sqrt();
            assert!((n - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn sizes_sum_to_n() {
        let x = clustered_data(30, 5);
        let km = KMeans::fit(&x, 5, 10, 6);
        assert_eq!(km.sizes.iter().sum::<usize>(), 90);
        assert_eq!(km.inverted_lists().iter().map(Vec::len).sum::<usize>(), 90);
    }

    #[test]
    fn best_balance_at_least_single_run() {
        let x = clustered_data(30, 7);
        let single = KMeans::fit(&x, 4, 10, 100);
        let multi = KMeans::fit_best_balance(&x, 4, 10, 4, 100);
        assert!(multi.balance() >= single.balance() - 1e-9);
    }

    #[test]
    fn inverted_lists_consistent_with_assign() {
        let x = clustered_data(20, 9);
        let km = KMeans::fit(&x, 3, 8, 10);
        for (j, list) in km.inverted_lists().iter().enumerate() {
            for &id in list {
                assert_eq!(km.assign[id as usize] as usize, j);
            }
        }
    }
}
