//! Sharded serving: partition the key set across N shards, build any
//! leaf backbone per shard from one inner [`IndexSpec`], fan search out
//! across the shards on the shared thread pool, and merge per-shard
//! top-k into a global top-k with shard-local ids remapped back to
//! global key ids.
//!
//! This is the partition-then-score backbone of large-scale MIPS
//! serving (ScaNN-style): one index per process caps database size and
//! leaves cores idle on large scans, while shards scale both. The merge
//! relies on the [`TopK`] invariant that merging per-shard top-k lists
//! equals top-k over the concatenated stream (ties broken toward lower
//! global id, NaN ranked worst) — property-tested in
//! `tests/properties.rs` — so a sharded flat index is *bit-identical*
//! to an unsharded [`crate::index::flat::FlatIndex`] at
//! [`Effort::Exhaustive`].
//!
//! Shard assignment is deterministic and arithmetic
//! ([`ShardAssign::RoundRobin`] interleaves ids, `Contiguous` cuts
//! ranges), so the local→global remap costs no memory and artifacts
//! stay small: the persisted payload is the assignment mode plus each
//! shard's own framed artifact (header + checksum), giving per-shard
//! integrity checking for free on reload.

use std::io::Read;

use anyhow::{bail, ensure, Context, Result};

use crate::api::{batch_map, Effort};
use crate::index::artifact;
use crate::index::spec::{BuildCtx, IndexSpec, ShardAssign, ShardedSpec};
use crate::index::traits::{SearchCost, SearchResult, TopK, VectorIndex};
use crate::tensor::Tensor;
use crate::util::threads::in_parallel_region;

/// Upper bound on the shard count — enforced symmetrically by
/// [`IndexSpec::validate`] at build/parse time and by
/// [`ShardedIndex::read_payload`] at load time (a corrupt count in an
/// artifact must fail fast instead of looping over garbage, and every
/// index that builds must reload).
pub const MAX_SHARDS: usize = 65_536;

/// Per-shard sizes for `n` keys over `shards` partitions: both
/// assignment modes balance to within one key (`n/shards` each, the
/// first `n % shards` shards taking one extra).
pub fn shard_sizes(n: usize, shards: usize) -> Vec<usize> {
    let base = n / shards;
    let rem = n % shards;
    (0..shards).map(|s| base + usize::from(s < rem)).collect()
}

/// Global key ids owned by shard `s` (ascending).
fn shard_member_ids(n: usize, shards: usize, assign: ShardAssign, s: usize) -> Vec<usize> {
    match assign {
        ShardAssign::RoundRobin => (s..n).step_by(shards).collect(),
        ShardAssign::Contiguous => {
            let sizes = shard_sizes(n, shards);
            let start: usize = sizes[..s].iter().sum();
            (start..start + sizes[s]).collect()
        }
    }
}

/// N shards of one inner backbone behind a single [`VectorIndex`].
pub struct ShardedIndex {
    shards: Vec<Box<dyn VectorIndex>>,
    assign: ShardAssign,
    /// Start of each shard's global-id range (contiguous mode only;
    /// empty for round-robin, where the remap is `local * S + s`).
    starts: Vec<usize>,
    len: usize,
    dim: usize,
}

impl ShardedIndex {
    /// Partition `keys` per `spec` and build the inner backbone over
    /// each shard (seed offset by shard index so per-shard k-means/PQ
    /// training draws independent streams).
    pub fn build(keys: &Tensor, spec: &ShardedSpec, ctx: &BuildCtx) -> Result<ShardedIndex> {
        let n = keys.rows();
        let s_count = spec.shards;
        ensure!(s_count >= 1, "sharded needs shards >= 1");
        ensure!(
            s_count <= MAX_SHARDS,
            "sharded(shards={s_count}) exceeds the supported maximum {MAX_SHARDS}"
        );
        ensure!(
            s_count <= n,
            "sharded(shards={s_count}) needs at least one key per shard, got {n} keys"
        );
        ensure!(
            !matches!(*spec.inner, IndexSpec::Sharded(_)),
            "nested sharding is not supported"
        );
        let mut shards: Vec<Box<dyn VectorIndex>> = Vec::with_capacity(s_count);
        for s in 0..s_count {
            let ids = shard_member_ids(n, s_count, spec.assign, s);
            let shard_keys = keys.gather_rows(&ids);
            let inner_ctx = BuildCtx {
                sample_queries: ctx.sample_queries,
                seed: ctx.seed.wrapping_add(s as u64),
            };
            let idx = spec
                .inner
                .build(&shard_keys, &inner_ctx)
                .with_context(|| format!("building shard {s}/{s_count} ({} keys)", ids.len()))?;
            shards.push(idx);
        }
        Self::from_parts(shards, spec.assign)
    }

    /// Assemble from already-built shards, verifying the invariants the
    /// id remap relies on: uniform dim and shard lengths matching the
    /// deterministic partition of the total key count. Artifacts that
    /// pass their per-shard checksums but violate these must error
    /// here, never panic on the first query.
    fn from_parts(shards: Vec<Box<dyn VectorIndex>>, assign: ShardAssign) -> Result<ShardedIndex> {
        ensure!(!shards.is_empty(), "sharded index has no shards");
        let dim = shards[0].dim();
        ensure!(
            shards.iter().all(|s| s.dim() == dim),
            "sharded index mixes key dims: {:?}",
            shards.iter().map(|s| s.dim()).collect::<Vec<_>>()
        );
        let len: usize = shards.iter().map(|s| s.len()).sum();
        let expect = shard_sizes(len, shards.len());
        let got: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        ensure!(
            got == expect,
            "shard lengths {got:?} do not partition {len} keys over {} shards (want {expect:?})",
            shards.len()
        );
        let starts = match assign {
            ShardAssign::RoundRobin => Vec::new(),
            ShardAssign::Contiguous => {
                let mut starts = Vec::with_capacity(shards.len());
                let mut acc = 0usize;
                for size in &expect {
                    starts.push(acc);
                    acc += size;
                }
                starts
            }
        };
        Ok(ShardedIndex {
            shards,
            assign,
            starts,
            len,
            dim,
        })
    }

    /// Deserialize from an artifact payload: assignment mode + each
    /// shard's own framed artifact (see [`crate::index::artifact`]).
    pub(crate) fn read_payload(r: &mut dyn Read) -> Result<ShardedIndex> {
        let assign = match artifact::r_u32(r)? {
            0 => ShardAssign::RoundRobin,
            1 => ShardAssign::Contiguous,
            other => bail!("invalid shard assignment tag {other} in artifact"),
        };
        let s_count = artifact::r_u64(r)? as usize;
        ensure!(
            (1..=MAX_SHARDS).contains(&s_count),
            "implausible shard count {s_count} in artifact"
        );
        let mut shards: Vec<Box<dyn VectorIndex>> = Vec::with_capacity(s_count);
        for s in 0..s_count {
            let bytes = artifact::r_u8s(r)?;
            // the spec grammar forbids nesting, so a nested tag is
            // corruption (or crafted recursion) — reject it from the
            // header alone, before load_from can recurse back here
            let header = artifact::read_header(&mut bytes.as_slice())
                .with_context(|| format!("reading shard {s}/{s_count} header"))?;
            ensure!(
                header.backbone != "sharded",
                "sharded artifact nests another sharded index at shard {s}"
            );
            let idx = artifact::load_from(&mut bytes.as_slice())
                .with_context(|| format!("loading shard {s}/{s_count}"))?;
            shards.push(idx);
        }
        Self::from_parts(shards, assign)
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn assign(&self) -> ShardAssign {
        self.assign
    }

    pub fn shard(&self, s: usize) -> &dyn VectorIndex {
        self.shards[s].as_ref()
    }

    /// Map a shard-local id back to the global key id.
    #[inline]
    fn global_id(&self, shard: usize, local: u32) -> u32 {
        match self.assign {
            ShardAssign::RoundRobin => local * self.shards.len() as u32 + shard as u32,
            ShardAssign::Contiguous => self.starts[shard] as u32 + local,
        }
    }
}

impl VectorIndex for ShardedIndex {
    fn name(&self) -> &str {
        "sharded"
    }

    fn len(&self) -> usize {
        self.len
    }

    fn dim(&self) -> usize {
        self.dim
    }

    /// Total coarse partitions across all shards; each shard resolves
    /// an [`Effort`] against its own cell count during fan-out.
    fn n_cells(&self) -> usize {
        self.shards.iter().map(|s| s.n_cells()).sum()
    }

    fn search_effort(&self, query: &[f32], k: usize, effort: Effort) -> SearchResult {
        let s_count = self.shards.len();
        // Fan out one task per shard on the shared pool — but only when
        // this query is not itself running on a pool worker (the blanket
        // batched Searcher already fans the batch out; nesting would
        // spawn workers-of-workers and oversubscribe the cores).
        let per_shard: Vec<SearchResult> = if s_count == 1 || in_parallel_region() {
            self.shards
                .iter()
                .map(|shard| shard.search_effort(query, k, effort))
                .collect()
        } else {
            batch_map(s_count, |s| self.shards[s].search_effort(query, k, effort))
        };
        let mut top = TopK::new(k);
        let mut cost = SearchCost::default();
        for (s, res) in per_shard.into_iter().enumerate() {
            for (&local, &score) in res.ids.iter().zip(&res.scores) {
                top.offer(score, self.global_id(s, local));
            }
            cost.add(res.cost);
        }
        let (ids, scores) = top.into_sorted();
        SearchResult { ids, scores, cost }
    }

    /// Fused batched fan-out: each shard receives the *whole sub-batch*
    /// (running its own fused scan over it) instead of one query at a
    /// time, and per-query merges remap shard-local ids exactly like the
    /// single-query path — so results and summed per-query costs are
    /// bit-identical to [`ShardedIndex::search_effort`] per row. Inside
    /// a pool worker the shard loop runs sequentially (the batch-level
    /// split above it owns the cores); on a free thread shards run
    /// concurrently, each still fused over the full batch.
    fn search_batch_effort(&self, queries: &Tensor, k: usize, effort: Effort) -> Vec<SearchResult> {
        let b = queries.rows();
        if b == 0 {
            return Vec::new();
        }
        let s_count = self.shards.len();
        let per_shard: Vec<Vec<SearchResult>> = if s_count == 1 || in_parallel_region() {
            self.shards
                .iter()
                .map(|shard| shard.search_batch_effort(queries, k, effort))
                .collect()
        } else {
            batch_map(s_count, |s| self.shards[s].search_batch_effort(queries, k, effort))
        };
        (0..b)
            .map(|q| {
                let mut top = TopK::new(k);
                let mut cost = SearchCost::default();
                for (s, results) in per_shard.iter().enumerate() {
                    let res = &results[q];
                    for (&local, &score) in res.ids.iter().zip(&res.scores) {
                        top.offer(score, self.global_id(s, local));
                    }
                    cost.add(res.cost);
                }
                let (ids, scores) = top.into_sorted();
                SearchResult { ids, scores, cost }
            })
            .collect()
    }

    fn spec(&self) -> IndexSpec {
        IndexSpec::Sharded(ShardedSpec {
            shards: self.shards.len(),
            assign: self.assign,
            inner: Box::new(self.shards[0].spec()),
        })
    }

    fn write_payload(&self, w: &mut Vec<u8>) -> Result<()> {
        artifact::w_u32(w, match self.assign {
            ShardAssign::RoundRobin => 0,
            ShardAssign::Contiguous => 1,
        })?;
        artifact::w_u64(w, self.shards.len() as u64)?;
        for shard in &self.shards {
            let mut buf = Vec::new();
            shard.save(&mut buf)?;
            artifact::w_u8s(w, &buf)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::flat::FlatIndex;
    use crate::tensor::normalize_rows;
    use crate::util::Rng;

    fn unit(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        normalize_rows(&mut t);
        t
    }

    fn sharded(spec_str: &str, keys: &Tensor, seed: u64) -> ShardedIndex {
        let IndexSpec::Sharded(spec) = spec_str.parse::<IndexSpec>().unwrap() else {
            panic!("not a sharded spec: {spec_str}");
        };
        ShardedIndex::build(keys, &spec, &BuildCtx::seeded(seed)).unwrap()
    }

    #[test]
    fn shard_sizes_partition_exactly() {
        for n in [1usize, 2, 7, 8, 100, 101] {
            for s in 1..=n.min(9) {
                let sizes = shard_sizes(n, s);
                assert_eq!(sizes.len(), s);
                assert_eq!(sizes.iter().sum::<usize>(), n, "n={n} s={s}");
                assert!(sizes.iter().all(|&v| v >= n / s && v <= n / s + 1));
            }
        }
    }

    #[test]
    fn member_ids_cover_every_key_once() {
        for assign in [ShardAssign::RoundRobin, ShardAssign::Contiguous] {
            let mut seen = vec![0usize; 23];
            for s in 0..5 {
                for id in shard_member_ids(23, 5, assign, s) {
                    seen[id] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "{assign:?}");
        }
    }

    #[test]
    fn remap_inverts_partition() {
        let keys = unit(&[37, 4], 1);
        for spec in [
            "sharded(shards=5,inner=flat)",
            "sharded(shards=5,assign=contiguous,inner=flat)",
        ] {
            let idx = sharded(spec, &keys, 2);
            for s in 0..idx.n_shards() {
                let members = shard_member_ids(37, 5, idx.assign(), s);
                for (local, &global) in members.iter().enumerate() {
                    assert_eq!(idx.global_id(s, local as u32) as usize, global, "{spec}");
                }
            }
        }
    }

    #[test]
    fn sharded_flat_exhaustive_is_bit_identical_to_flat() {
        let keys = unit(&[211, 12], 3);
        let flat = FlatIndex::new(keys.clone());
        for spec in [
            "sharded(shards=4,inner=flat)",
            "sharded(shards=4,assign=contiguous,inner=flat)",
        ] {
            let idx = sharded(spec, &keys, 4);
            assert_eq!((idx.len(), idx.dim()), (211, 12));
            let q = unit(&[8, 12], 5);
            for i in 0..8 {
                let a = idx.search_effort(q.row(i), 7, Effort::Exhaustive);
                let b = flat.search_effort(q.row(i), 7, Effort::Exhaustive);
                assert_eq!(a.ids, b.ids, "{spec} q{i}");
                assert_eq!(a.scores, b.scores, "{spec} q{i}");
                assert_eq!(a.cost.keys_scanned, 211);
            }
        }
    }

    #[test]
    fn batched_search_is_bit_identical_to_per_query() {
        let keys = unit(&[180, 8], 40);
        for spec in [
            "sharded(shards=3,inner=flat)",
            "sharded(shards=4,assign=contiguous,inner=ivf(nlist=3))",
        ] {
            let idx = sharded(spec, &keys, 41);
            let q = unit(&[6, 8], 42);
            for effort in [Effort::Probes(2), Effort::Auto, Effort::Exhaustive] {
                let batched = idx.search_batch_effort(&q, 5, effort);
                for i in 0..6 {
                    let single = idx.search_effort(q.row(i), 5, effort);
                    assert_eq!(batched[i].ids, single.ids, "{spec} {effort:?} q{i}");
                    assert_eq!(batched[i].scores, single.scores, "{spec} {effort:?} q{i}");
                    assert_eq!(batched[i].cost, single.cost, "{spec} {effort:?} q{i}");
                }
            }
        }
    }

    #[test]
    fn sharded_artifact_round_trips() {
        let keys = unit(&[120, 8], 6);
        let idx = sharded("sharded(shards=3,inner=ivf(nlist=4))", &keys, 7);
        assert_eq!(idx.n_cells(), 12);
        let mut bytes = Vec::new();
        idx.save(&mut bytes).unwrap();
        let loaded = artifact::load_from(&mut bytes.as_slice()).unwrap();
        assert_eq!(loaded.name(), "sharded");
        assert_eq!(loaded.spec(), idx.spec());
        let q = unit(&[3, 8], 8);
        for i in 0..3 {
            let a = idx.search_effort(q.row(i), 5, Effort::Probes(2));
            let b = loaded.search_effort(q.row(i), 5, Effort::Probes(2));
            assert_eq!(a.ids, b.ids, "q{i}");
            assert_eq!(a.scores, b.scores, "q{i}");
        }
    }

    #[test]
    fn build_rejects_more_shards_than_keys() {
        let keys = unit(&[3, 4], 9);
        let IndexSpec::Sharded(spec) = "sharded(shards=5,inner=flat)".parse().unwrap() else {
            unreachable!()
        };
        assert!(ShardedIndex::build(&keys, &spec, &BuildCtx::seeded(1)).is_err());
    }

    #[test]
    fn spec_echo_reports_resolved_inner_knobs() {
        // pq m=auto resolves against the key dim inside every shard
        let keys = unit(&[40, 12], 10);
        let idx = sharded("sharded(shards=2,inner=pq)", &keys, 11);
        assert_eq!(
            idx.spec().to_string(),
            "sharded(shards=2,assign=round_robin,inner=pq(m=4,iters=10,eta=1))"
        );
    }
}
