//! Versioned binary index artifacts (`.ami`): build an index once, ship
//! its trained state (centroids, codebooks, projections, packed
//! storage) to every serving replica, and reload it without re-running
//! k-means/PQ training.
//!
//! Layout (little-endian throughout):
//!
//! ```text
//! magic    b"AMIX"
//! version  u32 (currently 3; version-1/2 artifacts still load)
//! backbone len-prefixed utf8 tag ("ivf", "scann", ...)
//! dim      u64
//! len      u64 (number of indexed keys)
//! spec     len-prefixed utf8 IndexSpec echo ("ivf(nlist=64,iters=15)")
//! pad      u32 length + zero bytes (v3+: places the payload base on a
//!          64-byte file offset)
//! payload  u64 length + backbone-specific bytes
//! checksum u64 FNV-1a over the payload
//! ```
//!
//! Version 3 is the *aligned* layout: inside the payload, every bulk
//! block (f32 key matrices, f16 rows, SQ8/PQ code matrices, id maps)
//! is written as a 64-byte-aligned, length-prefixed section with an
//! explicit self-describing pad. Because the payload base itself lands
//! on a 64-byte file offset (and mappings are page-aligned), a reader
//! holding the file as an `Arc<Mapped>` can serve those sections as
//! borrowed [`Section`] views — the kernels then scan straight from
//! the page cache with zero deserialize. Readers go through [`Src`],
//! which remembers the backing mapping; misaligned sections, RAM-backed
//! buffers on odd addresses, or big-endian hosts silently fall back to
//! the decode-and-copy path (checked in [`Section::view`], never UB).
//!
//! Every [`VectorIndex`] knows how to write its payload
//! ([`VectorIndex::write_payload`]) and the framed artifact
//! ([`VectorIndex::save`]); [`load`]/[`load_from`] read the header,
//! verify the checksum and dispatch on the backbone tag. Corrupt
//! headers, short reads and checksum mismatches are errors, never
//! panics. One deliberate exception: [`load`] of a *mapped* v3 file
//! skips the full-payload checksum — verifying it would fault in every
//! page and defeat the O(1) lazy open — and relies on the structural
//! bounds checks instead; byte-stream loads and pre-v3 files verify in
//! full as before.

use std::io::{Read, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::index::{flat, ivf, leanvec, pq, scann, shard, soar, sq, VectorIndex};
use crate::tensor::mapped::{stats, Mapped, Pod, Section};
use crate::tensor::Tensor;

/// Artifact magic bytes.
pub const MAGIC: &[u8; 4] = b"AMIX";
/// Current artifact format version. Version 2 added the compact-storage
/// payload fields (`storage=f16` key matrices, 4-bit packed PQ codes);
/// version 3 is the 64-byte-aligned zero-copy layout. Writers always
/// emit the current version.
pub const VERSION: u32 = 3;
/// Oldest artifact version this build still reads. Version-1/2 payloads
/// decode bit-identically to the build that wrote them, through the
/// decode-into-RAM path.
pub const MIN_VERSION: u32 = 1;
/// Conventional file extension for index artifacts.
pub const EXTENSION: &str = "ami";
/// Upper bound on any element count read from disk — corrupt length
/// fields must fail fast instead of attempting a huge allocation.
const MAX_ELEMS: u64 = 1 << 31;
/// Alignment of every bulk section in a v3 payload. 64 divides the
/// 4096-byte page size, so page-aligned mappings keep it for free, and
/// it covers every vector ISA this repo dispatches to (AVX-512 wants
/// at most 64).
pub(crate) const SECTION_ALIGN: usize = 64;

/// Parsed artifact header (everything before the payload).
pub struct ArtifactHeader {
    pub version: u32,
    pub backbone: String,
    pub dim: usize,
    pub len: usize,
    pub spec: String,
}

/// FNV-1a 64-bit over `bytes`.
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// Primitive write/read helpers shared by the backbone payload codecs.
// ---------------------------------------------------------------------------

pub(crate) fn w_u32(w: &mut dyn Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub(crate) fn w_u64(w: &mut dyn Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub(crate) fn w_f32(w: &mut dyn Write, v: f32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub(crate) fn w_bool(w: &mut dyn Write, v: bool) -> Result<()> {
    w_u32(w, v as u32)
}

pub(crate) fn w_str(w: &mut dyn Write, s: &str) -> Result<()> {
    w_u32(w, s.len() as u32)?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

pub(crate) fn w_u8s(w: &mut dyn Write, v: &[u8]) -> Result<()> {
    w_u64(w, v.len() as u64)?;
    w.write_all(v)?;
    Ok(())
}

pub(crate) fn w_u32s(w: &mut dyn Write, v: &[u32]) -> Result<()> {
    w_u64(w, v.len() as u64)?;
    let mut buf = Vec::with_capacity(v.len() * 4);
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

pub(crate) fn w_f32s(w: &mut dyn Write, v: &[f32]) -> Result<()> {
    w_u64(w, v.len() as u64)?;
    let mut buf = Vec::with_capacity(v.len() * 4);
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

pub(crate) fn w_usizes(w: &mut dyn Write, v: &[usize]) -> Result<()> {
    w_u64(w, v.len() as u64)?;
    for &x in v {
        w_u64(w, x as u64)?;
    }
    Ok(())
}

pub(crate) fn w_u16s(w: &mut dyn Write, v: &[u16]) -> Result<()> {
    w_u64(w, v.len() as u64)?;
    let mut buf = Vec::with_capacity(v.len() * 2);
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

pub(crate) fn w_tensor(w: &mut dyn Write, t: &Tensor) -> Result<()> {
    let mut w = w;
    t.write_to(&mut w)
}

pub(crate) fn r_u32(r: &mut dyn Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("artifact truncated")?;
    Ok(u32::from_le_bytes(b))
}

pub(crate) fn r_u64(r: &mut dyn Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b).context("artifact truncated")?;
    Ok(u64::from_le_bytes(b))
}

pub(crate) fn r_f32(r: &mut dyn Read) -> Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("artifact truncated")?;
    Ok(f32::from_le_bytes(b))
}

pub(crate) fn r_bool(r: &mut dyn Read) -> Result<bool> {
    match r_u32(r)? {
        0 => Ok(false),
        1 => Ok(true),
        other => bail!("invalid bool encoding {other} in artifact"),
    }
}

fn checked_len(v: u64, what: &str) -> Result<usize> {
    ensure!(v <= MAX_ELEMS, "implausible {what} length {v} in artifact");
    Ok(v as usize)
}

pub(crate) fn r_str(r: &mut dyn Read) -> Result<String> {
    let n = r_u32(r)? as usize;
    ensure!(n <= 65_536, "implausible string length {n} in artifact");
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf).context("artifact truncated")?;
    Ok(String::from_utf8(buf)?)
}

pub(crate) fn r_u8s(r: &mut dyn Read) -> Result<Vec<u8>> {
    let n = checked_len(r_u64(r)?, "byte array")?;
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf).context("artifact truncated")?;
    Ok(buf)
}

pub(crate) fn r_u32s(r: &mut dyn Read) -> Result<Vec<u32>> {
    let n = checked_len(r_u64(r)?, "u32 array")?;
    let mut raw = vec![0u8; n * 4];
    r.read_exact(&mut raw).context("artifact truncated")?;
    Ok(raw
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

pub(crate) fn r_f32s(r: &mut dyn Read) -> Result<Vec<f32>> {
    let n = checked_len(r_u64(r)?, "f32 array")?;
    let mut raw = vec![0u8; n * 4];
    r.read_exact(&mut raw).context("artifact truncated")?;
    Ok(raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

pub(crate) fn r_usizes(r: &mut dyn Read) -> Result<Vec<usize>> {
    let n = checked_len(r_u64(r)?, "usize array")?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(checked_len(r_u64(r)?, "usize element")?);
    }
    Ok(out)
}

pub(crate) fn r_u16s(r: &mut dyn Read) -> Result<Vec<u16>> {
    let n = checked_len(r_u64(r)?, "u16 array")?;
    let mut raw = vec![0u8; n * 2];
    r.read_exact(&mut raw).context("artifact truncated")?;
    Ok(raw
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes([c[0], c[1]]))
        .collect())
}

pub(crate) fn r_tensor(r: &mut dyn Read) -> Result<Tensor> {
    let mut r = r;
    Tensor::read_from(&mut r)
}

// ---------------------------------------------------------------------------
// Src: the payload cursor the zero-copy readers decode through.
// ---------------------------------------------------------------------------

/// A payload cursor over an in-memory byte slice that remembers the
/// backing [`Mapped`] buffer (when there is one), so section readers
/// can hand out borrowed [`Section`] views instead of copies. It
/// implements [`Read`], so every legacy `r_*` helper works on it
/// unchanged — version-stable payload fields keep their old codecs.
pub struct Src<'a> {
    buf: &'a [u8],
    pos: usize,
    map: Option<&'a Arc<Mapped>>,
    /// Byte offset of `buf[0]` within `map` (0 when unmapped).
    base: usize,
}

impl<'a> Src<'a> {
    /// Cursor over plain bytes — every section decodes by copy.
    pub fn new(buf: &'a [u8]) -> Src<'a> {
        Src {
            buf,
            pos: 0,
            map: None,
            base: 0,
        }
    }

    /// Cursor over `buf`, which must be a subslice of `map`'s bytes —
    /// aligned sections then decode as borrowed views of the mapping.
    pub fn mapped(buf: &'a [u8], map: &'a Arc<Mapped>) -> Src<'a> {
        let base = (buf.as_ptr() as usize).wrapping_sub(map.as_slice().as_ptr() as usize);
        debug_assert!(base.checked_add(buf.len()).is_some_and(|e| e <= map.len()));
        Src {
            buf,
            pos: 0,
            map: Some(map),
            base,
        }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    /// Whether the cursor is backed by a real file mapping (not a RAM
    /// fallback buffer).
    fn backed_by_map(&self) -> bool {
        self.map.is_some_and(|m| m.is_map())
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.remaining() >= n,
            "artifact truncated: wanted {n} bytes, {} remain",
            self.remaining()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
}

impl Read for Src<'_> {
    fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
        let n = out.len().min(self.remaining());
        out[..n].copy_from_slice(&self.buf[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

// ---------------------------------------------------------------------------
// Aligned (v3) section codecs.
// ---------------------------------------------------------------------------

/// Pad `w` with a self-describing gap (u32 pad length + that many zero
/// bytes) so the next byte lands on a [`SECTION_ALIGN`] boundary
/// relative to the payload start. Framing places the payload base on a
/// 64-byte *file* offset, so payload-relative alignment is file (and
/// mapping) alignment.
pub(crate) fn w_align(w: &mut Vec<u8>) -> Result<()> {
    let pad = (SECTION_ALIGN - ((w.len() + 4) % SECTION_ALIGN)) % SECTION_ALIGN;
    w_u32(w, pad as u32)?;
    w.resize(w.len() + pad, 0);
    Ok(())
}

/// Consume a pad written by [`w_align`].
pub(crate) fn r_align(src: &mut Src) -> Result<()> {
    let pad = r_u32(&mut *src)? as usize;
    ensure!(
        pad < SECTION_ALIGN,
        "implausible section pad {pad} in artifact"
    );
    src.take(pad)
        .context("artifact truncated inside section pad")?;
    Ok(())
}

fn w_section_raw(w: &mut Vec<u8>, n: usize, bytes: impl FnOnce(&mut Vec<u8>)) -> Result<()> {
    w_u64(w, n as u64)?;
    w_align(w)?;
    bytes(w);
    Ok(())
}

/// Aligned byte-matrix section (PQ/SQ8 code matrices).
pub(crate) fn w_section_u8s(w: &mut Vec<u8>, v: &[u8]) -> Result<()> {
    w_section_raw(w, v.len(), |w| w.extend_from_slice(v))
}

/// Aligned u16 section (f16 key rows).
pub(crate) fn w_section_u16s(w: &mut Vec<u8>, v: &[u16]) -> Result<()> {
    w_section_raw(w, v.len(), |w| {
        for &x in v {
            w.extend_from_slice(&x.to_le_bytes());
        }
    })
}

/// Aligned u32 section (sealed-segment id maps).
pub(crate) fn w_section_u32s(w: &mut Vec<u8>, v: &[u32]) -> Result<()> {
    w_section_raw(w, v.len(), |w| {
        for &x in v {
            w.extend_from_slice(&x.to_le_bytes());
        }
    })
}

/// Aligned f32 section (key matrices).
pub(crate) fn w_section_f32s(w: &mut Vec<u8>, v: &[f32]) -> Result<()> {
    w_section_raw(w, v.len(), |w| {
        for &x in v {
            w.extend_from_slice(&x.to_le_bytes());
        }
    })
}

/// Read an aligned section: a borrowed view of the backing mapping
/// when the checked accessor admits it, a decoded copy otherwise.
pub(crate) fn r_section<T: Pod>(src: &mut Src) -> Result<Section<T>> {
    let n = checked_len(r_u64(&mut *src)?, "section")?;
    r_align(src)?;
    let bytes = n
        .checked_mul(std::mem::size_of::<T>())
        .context("section byte length overflows")?;
    let off = src.base + src.pos;
    let map = src.map.cloned();
    let raw = src.take(bytes).context("artifact section truncated")?;
    if let Some(map) = &map {
        if let Some(sec) = Section::<T>::view(map, off, n) {
            return Ok(sec);
        }
    }
    stats::add_copied(bytes as u64);
    Ok(Section::from_le_bytes(raw))
}

/// v3 tensor codec: rank + dims, then an aligned f32 section. (The
/// legacy `w_tensor`/`r_tensor` codec — magic-prefixed, unaligned —
/// stays for version-stable payload fields and `.amt` files.)
pub(crate) fn w_tensor_v3(w: &mut Vec<u8>, t: &Tensor) -> Result<()> {
    w_u32(w, t.shape().len() as u32)?;
    for &d in t.shape() {
        w_u64(w, d as u64)?;
    }
    w_section_f32s(w, t.data())
}

pub(crate) fn r_tensor_v3(src: &mut Src) -> Result<Tensor> {
    let rank = r_u32(&mut *src)? as usize;
    ensure!(rank <= 8, "implausible tensor rank {rank} in artifact");
    let mut shape = Vec::with_capacity(rank);
    for _ in 0..rank {
        let dim = r_u64(&mut *src)?;
        ensure!(
            dim > 0 && dim <= MAX_ELEMS,
            "implausible tensor dim {dim} in artifact"
        );
        shape.push(dim as usize);
    }
    let n = match shape.iter().try_fold(1usize, |acc, &d| acc.checked_mul(d)) {
        Some(n) if n as u64 <= MAX_ELEMS => n,
        _ => bail!("implausible tensor element count for shape {shape:?}"),
    };
    let data: Section<f32> = r_section(src)?;
    ensure!(
        data.len() == n,
        "tensor section holds {} elements, shape {shape:?} wants {n}",
        data.len()
    );
    Ok(Tensor::from_section(&shape, data))
}

// ---------------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------------

/// Write a complete framed artifact: header, pad (so the payload base
/// sits on a 64-byte file offset), payload, checksum.
pub(crate) fn write_framed(
    w: &mut dyn Write,
    backbone: &str,
    dim: usize,
    len: usize,
    spec: &str,
    payload: &[u8],
) -> Result<()> {
    let mut head = Vec::with_capacity(64 + backbone.len() + spec.len());
    head.extend_from_slice(MAGIC);
    w_u32(&mut head, VERSION)?;
    w_str(&mut head, backbone)?;
    w_u64(&mut head, dim as u64)?;
    w_u64(&mut head, len as u64)?;
    w_str(&mut head, spec)?;
    // self-describing pad so that after the pad AND the payload-length
    // u64, the payload base is SECTION_ALIGN-aligned from frame start
    let pad = (SECTION_ALIGN - ((head.len() + 4 + 8) % SECTION_ALIGN)) % SECTION_ALIGN;
    w_u32(&mut head, pad as u32)?;
    head.resize(head.len() + pad, 0);
    w_u64(&mut head, payload.len() as u64)?;
    w.write_all(&head)?;
    w.write_all(payload)?;
    w_u64(w, fnv1a64(payload))?;
    Ok(())
}

/// Read and validate the artifact header (magic, version, tag, shape,
/// spec echo), leaving the reader positioned at the header pad (v3+)
/// or the payload length (v1/v2).
pub fn read_header(r: &mut dyn Read) -> Result<ArtifactHeader> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)
        .context("reading index artifact magic")?;
    ensure!(
        &magic == MAGIC,
        "bad index artifact magic {magic:?} (expected {MAGIC:?})"
    );
    let version = r_u32(r)?;
    ensure!(
        (MIN_VERSION..=VERSION).contains(&version),
        "unsupported index artifact version {version} \
         (this build reads versions {MIN_VERSION}..={VERSION})"
    );
    let backbone = r_str(r)?;
    let dim = checked_len(r_u64(r)?, "dim")?;
    let len = checked_len(r_u64(r)?, "len")?;
    let spec = r_str(r)?;
    Ok(ArtifactHeader {
        version,
        backbone,
        dim,
        len,
        spec,
    })
}

/// Consume the v3 header pad (no-op for earlier versions).
fn skip_header_pad(r: &mut dyn Read, version: u32) -> Result<()> {
    if version < 3 {
        return Ok(());
    }
    let pad = r_u32(r)? as usize;
    ensure!(
        pad < SECTION_ALIGN,
        "implausible header pad {pad} in artifact"
    );
    let mut buf = [0u8; SECTION_ALIGN];
    r.read_exact(&mut buf[..pad])
        .context("artifact truncated inside header pad")?;
    Ok(())
}

/// Dispatch one decoded payload on the backbone tag. Backbones whose
/// payloads changed across versions take the header version; the rest
/// are version-stable (the sharded payload embeds fully framed
/// per-shard artifacts, which carry their own versions).
fn decode_backbone(header: &ArtifactHeader, cur: &mut Src) -> Result<Box<dyn VectorIndex>> {
    let v = header.version;
    let index: Box<dyn VectorIndex> = match header.backbone.as_str() {
        "flat" => Box::new(flat::FlatIndex::read_payload(cur, v)?),
        "ivf" => Box::new(ivf::IvfIndex::read_payload(&mut *cur)?),
        "pq" => Box::new(pq::PqIndex::read_payload(cur, v)?),
        "sq8" => Box::new(sq::SqIndex::read_payload(cur, v)?),
        "scann" => Box::new(scann::ScannIndex::read_payload(&mut *cur, v)?),
        "soar" => Box::new(soar::SoarIndex::read_payload(&mut *cur)?),
        "leanvec" => Box::new(leanvec::LeanVecIndex::read_payload(cur, v)?),
        "sharded" => Box::new(shard::ShardedIndex::read_payload(&mut *cur)?),
        other => bail!("unknown backbone tag '{other}' in index artifact"),
    };
    ensure!(
        index.dim() == header.dim && index.len() == header.len,
        "artifact header advertises {}x{} but the payload decodes to {}x{}",
        header.len,
        header.dim,
        index.len(),
        index.dim()
    );
    Ok(index)
}

/// Load a boxed index from any byte stream, verifying the checksum
/// before a single payload byte is interpreted. This path always
/// decodes into RAM (no mapping to borrow from).
pub fn load_from(r: &mut dyn Read) -> Result<Box<dyn VectorIndex>> {
    let header = read_header(r)?;
    skip_header_pad(r, header.version)?;
    let plen = checked_len(r_u64(r)?, "payload")?;
    let mut payload = vec![0u8; plen];
    r.read_exact(&mut payload)
        .with_context(|| format!("index artifact truncated: expected a {plen}-byte payload"))?;
    let want = r_u64(r).context("index artifact truncated: missing checksum")?;
    let got = fnv1a64(&payload);
    ensure!(
        got == want,
        "index artifact checksum mismatch (stored {want:#018x}, computed {got:#018x}): corrupt file"
    );
    decode_backbone(&header, &mut Src::new(&payload))
}

/// Decode one framed artifact starting at `src`'s position, serving
/// aligned sections as borrowed views of `src`'s backing mapping.
///
/// Lazy-open rule: for a v3 frame on a *real* mapping, the full-payload
/// checksum is skipped — verifying it would fault in every page, making
/// cold open O(corpus) again. The structural bounds checks (section
/// pads, lengths, shape cross-checks) still run; RAM-backed buffers and
/// pre-v3 frames verify the checksum in full.
pub(crate) fn load_from_src(src: &mut Src) -> Result<Box<dyn VectorIndex>> {
    let header = read_header(&mut *src)?;
    skip_header_pad(&mut *src, header.version)?;
    let plen = checked_len(r_u64(&mut *src)?, "payload")?;
    let off = src.base + src.pos;
    let map = src.map;
    let payload = src.take(plen).with_context(|| {
        format!("index artifact truncated: expected a {plen}-byte payload")
    })?;
    let want = r_u64(&mut *src).context("index artifact truncated: missing checksum")?;
    let lazy = header.version >= 3 && src.backed_by_map();
    if !lazy {
        let got = fnv1a64(payload);
        ensure!(
            got == want,
            "index artifact checksum mismatch (stored {want:#018x}, computed {got:#018x}): corrupt file"
        );
    }
    let mut cur = match map {
        Some(m) => {
            debug_assert_eq!(off, (payload.as_ptr() as usize) - (m.as_slice().as_ptr() as usize));
            Src::mapped(payload, m)
        }
        None => Src::new(payload),
    };
    decode_backbone(&header, &mut cur)
}

/// Load an index artifact from a shared mapping (zero-copy when the
/// layout allows; the decode-into-RAM fallback otherwise). `label` is
/// only used in the legacy-fallback warning.
pub fn load_mapped(map: &Arc<Mapped>, label: &str) -> Result<Box<dyn VectorIndex>> {
    let mut src = Src::mapped(map.as_slice(), map);
    // peek the version for the one-line legacy warning without
    // disturbing the cursor
    if map.is_map() && map.len() >= 8 {
        let v = u32::from_le_bytes([map[4], map[5], map[6], map[7]]);
        if (MIN_VERSION..3).contains(&v) {
            eprintln!(
                "amips: {label}: legacy v{v} artifact under mmap — decoding by copy \
                 (re-save to get the zero-copy v{VERSION} layout)"
            );
            stats::add_copied(map.len() as u64);
        }
    }
    load_from_src(&mut src)
}

/// Load an index artifact from disk, through a shared [`Mapped`]
/// buffer: mmap under `--features mmap` (v3 artifacts then serve their
/// key/code sections straight from the page cache), a whole-file read
/// otherwise.
pub fn load(path: &Path) -> Result<Box<dyn VectorIndex>> {
    let map = Arc::new(
        Mapped::open(path)
            .with_context(|| format!("opening index artifact {}", path.display()))?,
    );
    load_mapped(&map, &path.display().to_string())
        .with_context(|| format!("loading index artifact {}", path.display()))
}

/// Save an index artifact to disk.
pub fn save(path: &Path, index: &dyn VectorIndex) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating index artifact {}", path.display()))?;
    let mut w = std::io::BufWriter::new(f);
    index.save(&mut w)?;
    w.flush()
        .with_context(|| format!("flushing index artifact {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_is_stable_and_input_sensitive() {
        // reference value for the empty input (FNV-1a offset basis)
        assert_eq!(fnv1a64(&[]), 0xcbf2_9ce4_8422_2325);
        assert_ne!(fnv1a64(b"abc"), fnv1a64(b"abd"));
    }

    #[test]
    fn primitives_round_trip() {
        let mut buf = Vec::new();
        w_u32(&mut buf, 7).unwrap();
        w_u64(&mut buf, 1 << 40).unwrap();
        w_f32(&mut buf, 2.5).unwrap();
        w_bool(&mut buf, true).unwrap();
        w_str(&mut buf, "scann").unwrap();
        w_u8s(&mut buf, &[1, 2, 3]).unwrap();
        w_u32s(&mut buf, &[9, 8]).unwrap();
        w_f32s(&mut buf, &[0.5, -1.0]).unwrap();
        w_usizes(&mut buf, &[4, 0, 11]).unwrap();
        w_u16s(&mut buf, &[515, 1027]).unwrap();
        let mut r: &[u8] = &buf;
        assert_eq!(r_u32(&mut r).unwrap(), 7);
        assert_eq!(r_u64(&mut r).unwrap(), 1 << 40);
        assert_eq!(r_f32(&mut r).unwrap(), 2.5);
        assert!(r_bool(&mut r).unwrap());
        assert_eq!(r_str(&mut r).unwrap(), "scann");
        assert_eq!(r_u8s(&mut r).unwrap(), vec![1, 2, 3]);
        assert_eq!(r_u32s(&mut r).unwrap(), vec![9, 8]);
        assert_eq!(r_f32s(&mut r).unwrap(), vec![0.5, -1.0]);
        assert_eq!(r_usizes(&mut r).unwrap(), vec![4, 0, 11]);
        assert_eq!(r_u16s(&mut r).unwrap(), vec![515, 1027]);
        assert!(r.is_empty());
    }

    #[test]
    fn truncated_primitives_error() {
        let mut buf = Vec::new();
        w_u64(&mut buf, 100).unwrap(); // promises 100 elements, delivers none
        let mut r: &[u8] = &buf;
        assert!(r_u8s(&mut r).is_err());
        let mut r: &[u8] = &[1, 2];
        assert!(r_u64(&mut r).is_err());
    }

    #[test]
    fn aligned_sections_round_trip_and_self_describe() {
        let mut buf = Vec::new();
        w_u32(&mut buf, 0xDEAD).unwrap(); // odd prefix: pad must adapt
        w_section_f32s(&mut buf, &[1.0, -2.5, 3.25]).unwrap();
        w_section_u8s(&mut buf, &[7, 8, 9]).unwrap();
        w_section_u16s(&mut buf, &[1000, 2000]).unwrap();
        w_section_u32s(&mut buf, &[5, 6]).unwrap();
        let mut src = Src::new(&buf);
        assert_eq!(r_u32(&mut src).unwrap(), 0xDEAD);
        let f: Section<f32> = r_section(&mut src).unwrap();
        assert_eq!(&f[..], &[1.0, -2.5, 3.25]);
        assert!(!f.is_view()); // no backing map
        let b: Section<u8> = r_section(&mut src).unwrap();
        assert_eq!(&b[..], &[7, 8, 9]);
        let h: Section<u16> = r_section(&mut src).unwrap();
        assert_eq!(&h[..], &[1000, 2000]);
        let u: Section<u32> = r_section(&mut src).unwrap();
        assert_eq!(&u[..], &[5, 6]);
        assert!(src.is_empty());
    }

    #[test]
    fn section_pad_lands_on_the_boundary() {
        for prefix in [0usize, 1, 4, 63, 64, 65, 100] {
            let mut buf = vec![0u8; prefix];
            w_align(&mut buf).unwrap();
            assert_eq!(buf.len() % SECTION_ALIGN, 0, "prefix {prefix}");
        }
    }

    #[test]
    fn bogus_section_pad_is_rejected() {
        let mut buf = Vec::new();
        w_u64(&mut buf, 1).unwrap(); // section length
        w_u32(&mut buf, 64).unwrap(); // pad claims >= SECTION_ALIGN
        buf.resize(buf.len() + 128, 0);
        let mut src = Src::new(&buf);
        assert!(r_section::<f32>(&mut src).is_err());
    }

    #[test]
    fn v3_tensor_codec_round_trips() {
        let t = Tensor::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let mut buf = Vec::new();
        w_tensor_v3(&mut buf, &t).unwrap();
        let back = r_tensor_v3(&mut Src::new(&buf)).unwrap();
        assert_eq!(back, t);
        // zero dim / hostile rank rejected
        let mut bad = Vec::new();
        w_u32(&mut bad, 2).unwrap();
        w_u64(&mut bad, 5).unwrap();
        w_u64(&mut bad, 0).unwrap();
        assert!(r_tensor_v3(&mut Src::new(&bad)).is_err());
    }

    #[test]
    fn mapped_src_serves_views_when_aligned() {
        let mut buf = Vec::new();
        w_section_f32s(&mut buf, &[0.5f32; 32]).unwrap();
        let map = Arc::new(Mapped::from_vec(buf));
        let mut src = Src::mapped(map.as_slice(), &map);
        let sec: Section<f32> = r_section(&mut src).unwrap();
        assert_eq!(&sec[..], &[0.5f32; 32]);
        // view iff the runtime base address is f32-aligned — either
        // way the decoded values are identical (checked above)
        let aligned = map.as_slice().as_ptr() as usize % 4 == 0;
        if cfg!(target_endian = "little") && aligned {
            assert!(sec.is_view());
        }
    }

    #[test]
    fn framed_payload_base_is_section_aligned() {
        for (backbone, spec) in [("ivf", "ivf(nlist=8,iters=15)"), ("flat", "flat")] {
            let mut buf = Vec::new();
            write_framed(&mut buf, backbone, 16, 400, spec, b"payload").unwrap();
            // payload base = total - payload - checksum
            let base = buf.len() - b"payload".len() - 8;
            assert_eq!(base % SECTION_ALIGN, 0, "{backbone}");
        }
    }

    #[test]
    fn header_round_trip_and_rejections() {
        let mut buf = Vec::new();
        write_framed(&mut buf, "ivf", 16, 400, "ivf(nlist=8,iters=15)", b"payload").unwrap();
        let mut r: &[u8] = &buf;
        let h = read_header(&mut r).unwrap();
        assert_eq!(h.version, VERSION);
        assert_eq!(h.backbone, "ivf");
        assert_eq!((h.dim, h.len), (16, 400));
        assert_eq!(h.spec, "ivf(nlist=8,iters=15)");

        // a version-1 header still parses (backwards compatibility)
        let mut v1 = buf.clone();
        v1[4] = 1;
        assert_eq!(read_header(&mut v1.as_slice()).unwrap().version, 1);
        // version 0 predates the format and is rejected
        let mut v0 = buf.clone();
        v0[4] = 0;
        assert!(read_header(&mut v0.as_slice()).is_err());

        // corrupt magic
        let mut bad = buf.clone();
        bad[0] ^= 0xFF;
        assert!(read_header(&mut bad.as_slice()).is_err());
        // unsupported version
        let mut bad = buf.clone();
        bad[4] = 0xEE;
        assert!(read_header(&mut bad.as_slice()).is_err());
        // checksum mismatch (flip one payload byte)
        let mut bad = buf.clone();
        let p = bad.len() - 9;
        bad[p] ^= 0x01;
        let err = load_from(&mut bad.as_slice()).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
    }
}
