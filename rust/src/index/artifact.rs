//! Versioned binary index artifacts (`.ami`): build an index once, ship
//! its trained state (centroids, codebooks, projections, packed
//! storage) to every serving replica, and reload it without re-running
//! k-means/PQ training.
//!
//! Layout (little-endian throughout, reusing the [`Tensor`] codec for
//! every dense block):
//!
//! ```text
//! magic    b"AMIX"
//! version  u32 (currently 2; version-1 artifacts still load)
//! backbone len-prefixed utf8 tag ("ivf", "scann", ...)
//! dim      u64
//! len      u64 (number of indexed keys)
//! spec     len-prefixed utf8 IndexSpec echo ("ivf(nlist=64,iters=15)")
//! payload  u64 length + backbone-specific bytes
//! checksum u64 FNV-1a over the payload
//! ```
//!
//! Every [`VectorIndex`] knows how to write its payload
//! ([`VectorIndex::write_payload`]) and the framed artifact
//! ([`VectorIndex::save`]); [`load`]/[`load_from`] read the header,
//! verify the checksum and dispatch on the backbone tag. Corrupt
//! headers, short reads and checksum mismatches are errors, never
//! panics.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::index::{flat, ivf, leanvec, pq, scann, shard, soar, sq, VectorIndex};
use crate::tensor::Tensor;

/// Artifact magic bytes.
pub const MAGIC: &[u8; 4] = b"AMIX";
/// Current artifact format version. Version 2 added the compact-storage
/// payload fields (`storage=f16` key matrices, 4-bit packed PQ codes);
/// writers always emit the current version.
pub const VERSION: u32 = 2;
/// Oldest artifact version this build still reads. Version-1 payloads
/// decode bit-identically to the build that wrote them (the readers
/// default the new fields to f32 storage / 8-bit codes).
pub const MIN_VERSION: u32 = 1;
/// Conventional file extension for index artifacts.
pub const EXTENSION: &str = "ami";
/// Upper bound on any element count read from disk — corrupt length
/// fields must fail fast instead of attempting a huge allocation.
const MAX_ELEMS: u64 = 1 << 31;

/// Parsed artifact header (everything before the payload).
pub struct ArtifactHeader {
    pub version: u32,
    pub backbone: String,
    pub dim: usize,
    pub len: usize,
    pub spec: String,
}

/// FNV-1a 64-bit over `bytes`.
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// Primitive write/read helpers shared by the backbone payload codecs.
// ---------------------------------------------------------------------------

pub(crate) fn w_u32(w: &mut dyn Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub(crate) fn w_u64(w: &mut dyn Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub(crate) fn w_f32(w: &mut dyn Write, v: f32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub(crate) fn w_bool(w: &mut dyn Write, v: bool) -> Result<()> {
    w_u32(w, v as u32)
}

pub(crate) fn w_str(w: &mut dyn Write, s: &str) -> Result<()> {
    w_u32(w, s.len() as u32)?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

pub(crate) fn w_u8s(w: &mut dyn Write, v: &[u8]) -> Result<()> {
    w_u64(w, v.len() as u64)?;
    w.write_all(v)?;
    Ok(())
}

pub(crate) fn w_u32s(w: &mut dyn Write, v: &[u32]) -> Result<()> {
    w_u64(w, v.len() as u64)?;
    let mut buf = Vec::with_capacity(v.len() * 4);
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

pub(crate) fn w_f32s(w: &mut dyn Write, v: &[f32]) -> Result<()> {
    w_u64(w, v.len() as u64)?;
    let mut buf = Vec::with_capacity(v.len() * 4);
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

pub(crate) fn w_usizes(w: &mut dyn Write, v: &[usize]) -> Result<()> {
    w_u64(w, v.len() as u64)?;
    for &x in v {
        w_u64(w, x as u64)?;
    }
    Ok(())
}

pub(crate) fn w_u16s(w: &mut dyn Write, v: &[u16]) -> Result<()> {
    w_u64(w, v.len() as u64)?;
    let mut buf = Vec::with_capacity(v.len() * 2);
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

pub(crate) fn w_tensor(w: &mut dyn Write, t: &Tensor) -> Result<()> {
    let mut w = w;
    t.write_to(&mut w)
}

pub(crate) fn r_u32(r: &mut dyn Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("artifact truncated")?;
    Ok(u32::from_le_bytes(b))
}

pub(crate) fn r_u64(r: &mut dyn Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b).context("artifact truncated")?;
    Ok(u64::from_le_bytes(b))
}

pub(crate) fn r_f32(r: &mut dyn Read) -> Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("artifact truncated")?;
    Ok(f32::from_le_bytes(b))
}

pub(crate) fn r_bool(r: &mut dyn Read) -> Result<bool> {
    match r_u32(r)? {
        0 => Ok(false),
        1 => Ok(true),
        other => bail!("invalid bool encoding {other} in artifact"),
    }
}

fn checked_len(v: u64, what: &str) -> Result<usize> {
    ensure!(v <= MAX_ELEMS, "implausible {what} length {v} in artifact");
    Ok(v as usize)
}

pub(crate) fn r_str(r: &mut dyn Read) -> Result<String> {
    let n = r_u32(r)? as usize;
    ensure!(n <= 65_536, "implausible string length {n} in artifact");
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf).context("artifact truncated")?;
    Ok(String::from_utf8(buf)?)
}

pub(crate) fn r_u8s(r: &mut dyn Read) -> Result<Vec<u8>> {
    let n = checked_len(r_u64(r)?, "byte array")?;
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf).context("artifact truncated")?;
    Ok(buf)
}

pub(crate) fn r_u32s(r: &mut dyn Read) -> Result<Vec<u32>> {
    let n = checked_len(r_u64(r)?, "u32 array")?;
    let mut raw = vec![0u8; n * 4];
    r.read_exact(&mut raw).context("artifact truncated")?;
    Ok(raw
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

pub(crate) fn r_f32s(r: &mut dyn Read) -> Result<Vec<f32>> {
    let n = checked_len(r_u64(r)?, "f32 array")?;
    let mut raw = vec![0u8; n * 4];
    r.read_exact(&mut raw).context("artifact truncated")?;
    Ok(raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

pub(crate) fn r_usizes(r: &mut dyn Read) -> Result<Vec<usize>> {
    let n = checked_len(r_u64(r)?, "usize array")?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(checked_len(r_u64(r)?, "usize element")?);
    }
    Ok(out)
}

pub(crate) fn r_u16s(r: &mut dyn Read) -> Result<Vec<u16>> {
    let n = checked_len(r_u64(r)?, "u16 array")?;
    let mut raw = vec![0u8; n * 2];
    r.read_exact(&mut raw).context("artifact truncated")?;
    Ok(raw
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes([c[0], c[1]]))
        .collect())
}

pub(crate) fn r_tensor(r: &mut dyn Read) -> Result<Tensor> {
    let mut r = r;
    Tensor::read_from(&mut r)
}

// ---------------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------------

/// Write a complete framed artifact: header, payload, checksum.
pub(crate) fn write_framed(
    w: &mut dyn Write,
    backbone: &str,
    dim: usize,
    len: usize,
    spec: &str,
    payload: &[u8],
) -> Result<()> {
    w.write_all(MAGIC)?;
    w_u32(w, VERSION)?;
    w_str(w, backbone)?;
    w_u64(w, dim as u64)?;
    w_u64(w, len as u64)?;
    w_str(w, spec)?;
    w_u64(w, payload.len() as u64)?;
    w.write_all(payload)?;
    w_u64(w, fnv1a64(payload))?;
    Ok(())
}

/// Read and validate the artifact header (magic, version, tag, shape,
/// spec echo), leaving the reader positioned at the payload length.
pub fn read_header(r: &mut dyn Read) -> Result<ArtifactHeader> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)
        .context("reading index artifact magic")?;
    ensure!(
        &magic == MAGIC,
        "bad index artifact magic {magic:?} (expected {MAGIC:?})"
    );
    let version = r_u32(r)?;
    ensure!(
        (MIN_VERSION..=VERSION).contains(&version),
        "unsupported index artifact version {version} \
         (this build reads versions {MIN_VERSION}..={VERSION})"
    );
    let backbone = r_str(r)?;
    let dim = checked_len(r_u64(r)?, "dim")?;
    let len = checked_len(r_u64(r)?, "len")?;
    let spec = r_str(r)?;
    Ok(ArtifactHeader {
        version,
        backbone,
        dim,
        len,
        spec,
    })
}

/// Load a boxed index from any reader, verifying the checksum before a
/// single payload byte is interpreted.
pub fn load_from(r: &mut dyn Read) -> Result<Box<dyn VectorIndex>> {
    let header = read_header(r)?;
    let plen = checked_len(r_u64(r)?, "payload")?;
    let mut payload = vec![0u8; plen];
    r.read_exact(&mut payload)
        .with_context(|| format!("index artifact truncated: expected a {plen}-byte payload"))?;
    let want = r_u64(r).context("index artifact truncated: missing checksum")?;
    let got = fnv1a64(&payload);
    ensure!(
        got == want,
        "index artifact checksum mismatch (stored {want:#018x}, computed {got:#018x}): corrupt file"
    );
    let mut cur: &[u8] = &payload;
    // Backbones whose payloads grew in v2 take the header version and
    // default the new fields when reading a v1 stream; the rest are
    // version-stable (the sharded payload embeds fully framed per-shard
    // artifacts, which carry their own versions).
    let v = header.version;
    let index: Box<dyn VectorIndex> = match header.backbone.as_str() {
        "flat" => Box::new(flat::FlatIndex::read_payload(&mut cur, v)?),
        "ivf" => Box::new(ivf::IvfIndex::read_payload(&mut cur)?),
        "pq" => Box::new(pq::PqIndex::read_payload(&mut cur, v)?),
        "sq8" => Box::new(sq::SqIndex::read_payload(&mut cur)?),
        "scann" => Box::new(scann::ScannIndex::read_payload(&mut cur, v)?),
        "soar" => Box::new(soar::SoarIndex::read_payload(&mut cur)?),
        "leanvec" => Box::new(leanvec::LeanVecIndex::read_payload(&mut cur, v)?),
        "sharded" => Box::new(shard::ShardedIndex::read_payload(&mut cur)?),
        other => bail!("unknown backbone tag '{other}' in index artifact"),
    };
    ensure!(
        index.dim() == header.dim && index.len() == header.len,
        "artifact header advertises {}x{} but the payload decodes to {}x{}",
        header.len,
        header.dim,
        index.len(),
        index.dim()
    );
    Ok(index)
}

/// Load an index artifact from disk.
pub fn load(path: &Path) -> Result<Box<dyn VectorIndex>> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening index artifact {}", path.display()))?;
    let mut r = std::io::BufReader::new(f);
    load_from(&mut r).with_context(|| format!("loading index artifact {}", path.display()))
}

/// Save an index artifact to disk.
pub fn save(path: &Path, index: &dyn VectorIndex) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating index artifact {}", path.display()))?;
    let mut w = std::io::BufWriter::new(f);
    index.save(&mut w)?;
    w.flush()
        .with_context(|| format!("flushing index artifact {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_is_stable_and_input_sensitive() {
        // reference value for the empty input (FNV-1a offset basis)
        assert_eq!(fnv1a64(&[]), 0xcbf2_9ce4_8422_2325);
        assert_ne!(fnv1a64(b"abc"), fnv1a64(b"abd"));
    }

    #[test]
    fn primitives_round_trip() {
        let mut buf = Vec::new();
        w_u32(&mut buf, 7).unwrap();
        w_u64(&mut buf, 1 << 40).unwrap();
        w_f32(&mut buf, 2.5).unwrap();
        w_bool(&mut buf, true).unwrap();
        w_str(&mut buf, "scann").unwrap();
        w_u8s(&mut buf, &[1, 2, 3]).unwrap();
        w_u32s(&mut buf, &[9, 8]).unwrap();
        w_f32s(&mut buf, &[0.5, -1.0]).unwrap();
        w_usizes(&mut buf, &[4, 0, 11]).unwrap();
        let mut r: &[u8] = &buf;
        assert_eq!(r_u32(&mut r).unwrap(), 7);
        assert_eq!(r_u64(&mut r).unwrap(), 1 << 40);
        assert_eq!(r_f32(&mut r).unwrap(), 2.5);
        assert!(r_bool(&mut r).unwrap());
        assert_eq!(r_str(&mut r).unwrap(), "scann");
        assert_eq!(r_u8s(&mut r).unwrap(), vec![1, 2, 3]);
        assert_eq!(r_u32s(&mut r).unwrap(), vec![9, 8]);
        assert_eq!(r_f32s(&mut r).unwrap(), vec![0.5, -1.0]);
        assert_eq!(r_usizes(&mut r).unwrap(), vec![4, 0, 11]);
        assert!(r.is_empty());
    }

    #[test]
    fn truncated_primitives_error() {
        let mut buf = Vec::new();
        w_u64(&mut buf, 100).unwrap(); // promises 100 elements, delivers none
        let mut r: &[u8] = &buf;
        assert!(r_u8s(&mut r).is_err());
        let mut r: &[u8] = &[1, 2];
        assert!(r_u64(&mut r).is_err());
    }

    #[test]
    fn header_round_trip_and_rejections() {
        let mut buf = Vec::new();
        write_framed(&mut buf, "ivf", 16, 400, "ivf(nlist=8,iters=15)", b"payload").unwrap();
        let mut r: &[u8] = &buf;
        let h = read_header(&mut r).unwrap();
        assert_eq!(h.version, VERSION);
        assert_eq!(h.backbone, "ivf");
        assert_eq!((h.dim, h.len), (16, 400));
        assert_eq!(h.spec, "ivf(nlist=8,iters=15)");

        // a version-1 header still parses (backwards compatibility)
        let mut v1 = buf.clone();
        v1[4] = 1;
        assert_eq!(read_header(&mut v1.as_slice()).unwrap().version, 1);
        // version 0 predates the format and is rejected
        let mut v0 = buf.clone();
        v0[4] = 0;
        assert!(read_header(&mut v0.as_slice()).is_err());

        // corrupt magic
        let mut bad = buf.clone();
        bad[0] ^= 0xFF;
        assert!(read_header(&mut bad.as_slice()).is_err());
        // unsupported version
        let mut bad = buf.clone();
        bad[4] = 0xEE;
        assert!(read_header(&mut bad.as_slice()).is_err());
        // checksum mismatch (flip one payload byte)
        let mut bad = buf.clone();
        let p = bad.len() - 9;
        bad[p] ^= 0x01;
        let err = load_from(&mut bad.as_slice()).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
    }
}
