//! LeanVec-analog backbone (Tepper et al. 2023): learn a linear
//! projection that preserves inner products, search an IVF index in the
//! reduced space, then re-rank candidates with full-dimension scores.
//!
//! The projection here is PCA over the keys (the canonical
//! inner-product-distortion minimizer for centered data); LeanVec's
//! query-aware refinement is approximated by optionally fitting PCA on
//! the union of keys and sample queries.

use anyhow::{ensure, Result};

use crate::api::Effort;
use crate::index::artifact::{self, Src};
use crate::index::ivf::IvfIndex;
use crate::index::keystore::{KeyStore, Storage};
use crate::index::spec::{IndexSpec, LeanVecSpec};
use crate::index::traits::{SearchResult, TopK, VectorIndex};
use crate::tensor::{dot, gemm_nt_tile, pca_project, power_iteration_pca, Tensor};

pub struct LeanVecIndex {
    d: usize,
    d_low: usize,
    comps: Tensor,  // [d_low, d]
    mean: Vec<f32>, // [d]
    inner: IvfIndex,
    /// Full-dim keys for re-ranking (f32 or compact f16 — the
    /// `leanvec(storage=...)` knob: LeanVec's whole premise is that
    /// full-precision rescoring memory dominates, so this is where the
    /// compact storage pays off most).
    keys: KeyStore,
    pub rerank: usize,
    /// Whether the projection was fitted on keys ∪ queries (spec echo).
    query_aware: bool,
}

impl LeanVecIndex {
    /// Build with target dimension `d_low`; optional `queries` sample
    /// makes the projection query-aware. `storage` selects the re-rank
    /// key precision.
    pub fn build(
        keys: &Tensor,
        d_low: usize,
        nlist: usize,
        queries: Option<&Tensor>,
        storage: Storage,
        seed: u64,
    ) -> LeanVecIndex {
        let d = keys.row_width();
        assert!(d_low <= d);
        // Fit the projection (query-aware if a sample is given).
        let fit_on = match queries {
            Some(q) => {
                let mut joint = Tensor::zeros(&[keys.rows() + q.rows(), d]);
                joint.data_mut()[..keys.len()].copy_from_slice(keys.data());
                joint.data_mut()[keys.len()..].copy_from_slice(q.data());
                joint
            }
            None => keys.clone(),
        };
        let (comps, mean) = power_iteration_pca(&fit_on, d_low, 20, seed);
        let low_keys = pca_project(keys, &comps, &mean);
        let inner = IvfIndex::build(&low_keys, nlist, 15, seed ^ 0x1EA);
        LeanVecIndex {
            d,
            d_low,
            comps,
            mean,
            inner,
            keys: KeyStore::new(keys.clone(), storage),
            rerank: 32,
            query_aware: queries.is_some(),
        }
    }

    /// Deserialize from an artifact payload (see [`crate::index::artifact`]).
    /// Version-1 payloads store the re-rank keys as a bare f32 tensor;
    /// version-2+ payloads carry a storage-tagged [`KeyStore`] (aligned,
    /// and zero-copy from a mapping, at version 3). The projection,
    /// mean and reduced-space IVF stay small, version-stable fields.
    pub(crate) fn read_payload(src: &mut Src, version: u32) -> Result<LeanVecIndex> {
        let comps = artifact::r_tensor(&mut *src)?;
        let mean = artifact::r_f32s(&mut *src)?;
        let keys = if version < 2 {
            KeyStore::F32(artifact::r_tensor(&mut *src)?)
        } else {
            KeyStore::read_payload(src, version)?
        };
        let inner = IvfIndex::read_payload(&mut *src)?;
        // clamp as in ScannIndex::read_payload: rerank > len is
        // behaviorally identical to len, and a crafted huge value must
        // not reach TopK's preallocation
        let rerank = (artifact::r_u64(&mut *src)? as usize).min(keys.len().max(1));
        let query_aware = artifact::r_bool(&mut *src)?;
        keys.advise_sequential();
        let d_low = comps.rows();
        let d = keys.dim();
        ensure!(
            comps.row_width() == d
                && mean.len() == d
                && inner.dim() == d_low
                && inner.len() == keys.len(),
            "inconsistent LeanVec payload: d={d}, d_low={d_low}, {} mean, inner {}x{}, {} keys",
            mean.len(),
            inner.len(),
            inner.dim(),
            keys.len()
        );
        Ok(LeanVecIndex {
            d,
            d_low,
            comps,
            mean,
            inner,
            keys,
            rerank,
            query_aware,
        })
    }

    fn project(&self, query: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.d_low];
        for c in 0..self.d_low {
            let v = self.comps.row(c);
            out[c] = dot(query, v) - dot(&self.mean, v);
        }
        out
    }

    /// Batched [`LeanVecIndex::project`]: batch × components in one gemm
    /// tile with the `<mean, comp_c>` terms hoisted — the same `dot`
    /// calls and the same subtraction per element, so each projected row
    /// is bit-identical to the per-query transform.
    fn project_batch(&self, queries: &Tensor) -> Tensor {
        let b = queries.rows();
        let mut low = Tensor::zeros(&[b, self.d_low]);
        gemm_nt_tile(queries.data(), self.comps.data(), self.d, low.data_mut());
        let mean_dots: Vec<f32> =
            (0..self.d_low).map(|c| dot(&self.mean, self.comps.row(c))).collect();
        for q in 0..b {
            for (o, md) in low.row_mut(q).iter_mut().zip(&mean_dots) {
                *o -= md;
            }
        }
        low
    }

    /// Stage 3 shared by the per-query and batched paths: full-dimension
    /// re-rank of the reduced-space candidates at the stored key
    /// precision (exact for f32 storage; f16 rescoring rounds each key
    /// element once but keeps the f32 accumulator).
    fn rerank_exact(&self, query: &[f32], cand: SearchResult, k: usize) -> SearchResult {
        let mut top = TopK::new(k);
        for &id in &cand.ids {
            top.offer(self.keys.score(query, id as usize), id);
        }
        let (ids, scores) = top.into_sorted();
        let mut cost = cand.cost;
        cost.flops += (self.d * self.d_low * 2) as u64; // projection
        cost.flops += (cand.ids.len() * self.d * 2) as u64; // re-rank
        SearchResult { ids, scores, cost }
    }
}

impl VectorIndex for LeanVecIndex {
    fn name(&self) -> &str {
        "leanvec"
    }

    fn len(&self) -> usize {
        self.keys.len()
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn n_cells(&self) -> usize {
        self.inner.nlist
    }

    fn search_effort(&self, query: &[f32], k: usize, effort: Effort) -> SearchResult {
        // Exhaustive effort widens the exact re-rank to the whole
        // database, so the answer is exact despite the lossy projection.
        let rerank = if effort.is_exhaustive() {
            self.len()
        } else {
            self.rerank
        };
        // 1. project the query (d * d_low multiply-adds)
        let q_low = self.project(query);
        // 2. search in the reduced space for rerank candidates
        let cand = self.inner.search_effort(&q_low, rerank.max(k), effort);
        // 3. exact full-dim re-rank
        self.rerank_exact(query, cand, k)
    }

    /// Fused batched search: one gemm-tile projection for the whole
    /// batch, the inner IVF's own fused batched scan in the reduced
    /// space, then per-query exact full-dim re-rank. Bit-identical to
    /// per-query [`LeanVecIndex::search_effort`].
    fn search_batch_effort(&self, queries: &Tensor, k: usize, effort: Effort) -> Vec<SearchResult> {
        if queries.rows() == 0 {
            return Vec::new();
        }
        let rerank = if effort.is_exhaustive() {
            self.len()
        } else {
            self.rerank
        };
        // Exhaustive-depth rerank would make the inner IVF hold `b`
        // candidate heaps of capacity n at once; the per-row scan is
        // bit-identical and peaks at one heap (the exact full-dim
        // re-rank dominates there anyway).
        if rerank.max(k) >= self.len().max(1) {
            return (0..queries.rows())
                .map(|q| self.search_effort(queries.row(q), k, effort))
                .collect();
        }
        let q_low = self.project_batch(queries);
        let cands = self.inner.search_batch_effort(&q_low, rerank.max(k), effort);
        cands
            .into_iter()
            .enumerate()
            .map(|(q, cand)| self.rerank_exact(queries.row(q), cand, k))
            .collect()
    }

    fn spec(&self) -> IndexSpec {
        IndexSpec::LeanVec(LeanVecSpec {
            d_low: Some(self.d_low),
            nlist: self.inner.nlist,
            query_aware: self.query_aware,
            storage: self.keys.storage(),
        })
    }

    fn write_payload(&self, w: &mut Vec<u8>) -> Result<()> {
        artifact::w_tensor(w, &self.comps)?;
        artifact::w_f32s(w, &self.mean)?;
        self.keys.write_payload(w)?;
        self.inner.write_payload(w)?;
        artifact::w_u64(w, self.rerank as u64)?;
        artifact::w_bool(w, self.query_aware)
    }

    fn zero_copy(&self) -> bool {
        self.keys.is_view()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::flat::FlatIndex;
    use crate::tensor::normalize_rows;
    use crate::util::Rng;

    fn unit_keys(n: usize, d: usize, seed: u64) -> Tensor {
        let mut t = Tensor::zeros(&[n, d]);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        normalize_rows(&mut t);
        t
    }

    #[test]
    fn full_probe_recall_reasonable() {
        let keys = unit_keys(500, 32, 1);
        let lv = LeanVecIndex::build(&keys, 16, 10, None, Storage::F32, 2);
        let flat = FlatIndex::new(keys.clone());
        let q = unit_keys(40, 32, 3);
        let mut hits = 0;
        for i in 0..40 {
            let truth = flat.search_effort(q.row(i), 1, Effort::Exhaustive).ids[0];
            let res = lv.search_effort(q.row(i), 5, Effort::Probes(10));
            if res.ids.contains(&truth) {
                hits += 1;
            }
        }
        assert!(hits >= 32, "recall@5 = {hits}/40");
    }

    #[test]
    fn reduced_scan_flops_below_flat() {
        let keys = unit_keys(600, 64, 4);
        let lv = LeanVecIndex::build(&keys, 16, 12, None, Storage::F32, 5);
        let q = unit_keys(1, 64, 6);
        let res = lv.search_effort(q.row(0), 1, Effort::Probes(3));
        let flat_flops = (600 * 64 * 2) as u64;
        assert!(res.cost.flops < flat_flops);
    }

    #[test]
    fn query_aware_projection_builds() {
        let keys = unit_keys(300, 32, 7);
        let queries = unit_keys(50, 32, 8);
        let lv = LeanVecIndex::build(&keys, 8, 6, Some(&queries), Storage::F32, 9);
        let res = lv.search_effort(queries.row(0), 3, Effort::Probes(2));
        assert_eq!(res.ids.len(), 3);
    }

    #[test]
    fn batched_search_is_bit_identical_to_per_query() {
        let keys = unit_keys(300, 24, 13);
        let q = unit_keys(7, 24, 15);
        for storage in [Storage::F32, Storage::F16] {
            let lv = LeanVecIndex::build(&keys, 8, 6, None, storage, 14);
            for effort in [Effort::Probes(2), Effort::Auto, Effort::Exhaustive] {
                let batched = lv.search_batch_effort(&q, 4, effort);
                for i in 0..7 {
                    let single = lv.search_effort(q.row(i), 4, effort);
                    assert_eq!(batched[i].ids, single.ids, "{storage:?} {effort:?} query {i}");
                    assert_eq!(
                        batched[i].scores, single.scores,
                        "{storage:?} {effort:?} query {i}"
                    );
                    assert_eq!(
                        batched[i].cost, single.cost,
                        "{storage:?} {effort:?} query {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn f16_storage_rescoring_stays_close_to_f32() {
        let keys = unit_keys(300, 32, 20);
        let q = unit_keys(8, 32, 21);
        let full = LeanVecIndex::build(&keys, 8, 6, None, Storage::F32, 22);
        let compact = LeanVecIndex::build(&keys, 8, 6, None, Storage::F16, 22);
        assert_eq!(
            compact.spec().to_string(),
            "leanvec(d_low=8,nlist=6,query_aware=false,storage=f16)"
        );
        for i in 0..8 {
            let a = full.search_effort(q.row(i), 3, Effort::Exhaustive);
            let b = compact.search_effort(q.row(i), 3, Effort::Exhaustive);
            // same candidate pipeline, keys rounded once to binary16:
            // scores differ only by that rounding
            for (x, y) in a.scores.iter().zip(&b.scores) {
                assert!((x - y).abs() <= 2e-2 * (1.0 + x.abs()), "query {i}: {x} vs {y}");
            }
            assert_eq!(a.cost, b.cost, "query {i}");
        }
    }

    #[test]
    fn exhaustive_effort_is_exact() {
        let keys = unit_keys(300, 32, 10);
        let lv = LeanVecIndex::build(&keys, 8, 6, None, Storage::F32, 11);
        let flat = FlatIndex::new(keys.clone());
        let q = unit_keys(10, 32, 12);
        for i in 0..10 {
            let a = lv.search_effort(q.row(i), 3, Effort::Exhaustive);
            let b = flat.search_effort(q.row(i), 3, Effort::Exhaustive);
            assert_eq!(a.ids, b.ids, "query {i}");
        }
    }
}
