//! [`MutableCollection`]: the user-facing mutable index handle.
//!
//! Layering (newest data first):
//!
//! ```text
//!   search ──fan-out──► delta (exact flat scan, in RAM)
//!                     ► sealed[N-1] … sealed[0] (any backbone)
//!                        └ per-segment tombstone masks
//!          merge per-segment TopK on *global* ids
//! ```
//!
//! Concurrency contract:
//! * mutations (`insert`/`upsert`/`delete`) and generation changes
//!   (`commit`/`compact`) are serialized by one mutex per collection;
//! * searches take the state read lock only, so they run concurrently
//!   with each other and with the slow offline part of a compaction —
//!   the only write-lock hold is the O(1) generation swap;
//! * global ids are assigned once and never reused, so results are
//!   stable across compactions (the acceptance bar: bit-identical
//!   search results across a generation swap).
//!
//! Durability contract: `commit()` seals the delta + tombstones into a
//! new generation manifest; `compact()` additionally folds everything
//! into one fresh sealed segment built through
//! [`IndexSpec::build`]. Mutations *between* commits live in RAM only
//! — a crash recovers to the last committed generation, exactly.

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{bail, ensure, Context, Result};

use crate::api::Effort;
use crate::index::spec::{BuildCtx, IndexSpec};
use crate::index::traits::{SearchCost, SearchResult, TopK, VectorIndex};
use crate::tensor::Tensor;

use super::delta::DeltaSegment;
use super::manifest::{self, GenManifest};
use super::sealed::SealedSegment;

/// Where one live global id currently resolves.
#[derive(Clone, Copy, Debug)]
enum Loc {
    /// Row index within the delta segment.
    Delta(usize),
    /// `(sealed segment index, local row)`.
    Sealed(usize, u32),
}

/// Everything searches read and mutations rewrite. Swapped wholesale
/// (under a brief write lock) when a generation commits.
struct State {
    gen: u64,
    next_id: u32,
    sealed: Vec<Arc<SealedSegment>>,
    /// Per sealed segment: local rows masked by a delete/upsert.
    dead: Vec<HashSet<u32>>,
    delta: DeltaSegment,
    /// Live gid → current location; absent means deleted or never
    /// assigned.
    locate: HashMap<u32, Loc>,
}

impl State {
    fn empty(dim: usize) -> State {
        State {
            gen: 0,
            next_id: 0,
            sealed: Vec::new(),
            dead: Vec::new(),
            delta: DeltaSegment::new(dim),
            locate: HashMap::new(),
        }
    }

    fn live_len(&self) -> usize {
        self.locate.len()
    }

    fn tombstones(&self) -> usize {
        self.dead.iter().map(|d| d.len()).sum()
    }
}

/// A mutable, crash-recoverable collection over immutable segments.
pub struct MutableCollection {
    dir: PathBuf,
    spec: IndexSpec,
    dim: usize,
    seed: u64,
    /// Serializes mutations and generation changes. Never held while
    /// waiting on searches.
    mutate: Mutex<()>,
    state: RwLock<State>,
}

impl MutableCollection {
    /// Initialize a fresh collection directory and commit generation 0
    /// (empty). Refuses a directory that already holds generations.
    pub fn create(dir: &Path, spec: IndexSpec, dim: usize, seed: u64) -> Result<MutableCollection> {
        ensure!(dim > 0, "collection dim must be positive");
        spec.validate()?;
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating collection directory {}", dir.display()))?;
        if !manifest::list_generations(dir)?.is_empty() {
            bail!(
                "collection directory {} already holds committed generations; open it instead",
                dir.display()
            );
        }
        let m = GenManifest {
            gen: 0,
            dim,
            seed,
            next_id: 0,
            segments: Vec::new(),
            tombstones: Vec::new(),
        };
        m.write(dir)?;
        Ok(MutableCollection {
            dir: dir.to_path_buf(),
            spec,
            dim,
            seed,
            mutate: Mutex::new(()),
            state: RwLock::new(State::empty(dim)),
        })
    }

    /// Reopen from the newest generation whose manifest *and* every
    /// listed segment fully validate. Torn or corrupt newer
    /// generations are skipped — that is the crash-recovery path: a
    /// kill mid-compaction leaves either a missing/torn `gen-<n+1>`
    /// (recover to `n`) or a complete one (recover to `n+1`), never
    /// anything in between.
    pub fn open(dir: &Path, spec: IndexSpec) -> Result<MutableCollection> {
        spec.validate()?;
        let gens = manifest::list_generations(dir)?;
        if gens.is_empty() {
            bail!(
                "no committed generations in collection directory {}",
                dir.display()
            );
        }
        let mut first_err = None;
        for (_, path) in &gens {
            match Self::load_generation(dir, path) {
                Ok((state, meta)) => {
                    return Ok(MutableCollection {
                        dir: dir.to_path_buf(),
                        spec,
                        dim: meta.dim,
                        seed: meta.seed,
                        mutate: Mutex::new(()),
                        state: RwLock::new(state),
                    });
                }
                Err(e) => {
                    first_err.get_or_insert(e);
                }
            }
        }
        Err(first_err.unwrap()).with_context(|| {
            format!(
                "no generation in {} survives validation ({} tried)",
                dir.display(),
                gens.len()
            )
        })
    }

    fn load_generation(dir: &Path, path: &Path) -> Result<(State, GenManifest)> {
        let m = GenManifest::read(path)?;
        ensure!(m.dim > 0, "generation manifest records dim 0");
        let mut sealed = Vec::with_capacity(m.segments.len());
        let mut by_file = HashMap::new();
        for (si, file) in m.segments.iter().enumerate() {
            let seg = Arc::new(SealedSegment::load(&dir.join(file))?);
            ensure!(
                seg.dim() == m.dim,
                "segment {file} has dim {} but the generation records {}",
                seg.dim(),
                m.dim
            );
            if by_file.insert(file.as_str(), si).is_some() {
                bail!("generation lists segment {file} twice");
            }
            sealed.push(seg);
        }
        let mut dead: Vec<HashSet<u32>> = vec![HashSet::new(); sealed.len()];
        for (file, lid) in &m.tombstones {
            let si = by_file[file.as_str()]; // parse() guarantees membership
            ensure!(
                (*lid as usize) < sealed[si].len(),
                "tombstone row {lid} out of range for segment {file}"
            );
            dead[si].insert(*lid);
        }
        let mut locate = HashMap::new();
        for (si, seg) in sealed.iter().enumerate() {
            for (lid, &gid) in seg.ids().iter().enumerate() {
                if dead[si].contains(&(lid as u32)) {
                    continue;
                }
                ensure!(
                    gid < m.next_id,
                    "segment {} holds id {gid} >= next_id {}",
                    seg.file(),
                    m.next_id
                );
                if locate.insert(gid, Loc::Sealed(si, lid as u32)).is_some() {
                    bail!("id {gid} is live in two segments: corrupt generation");
                }
            }
        }
        let state = State {
            gen: m.gen,
            next_id: m.next_id,
            delta: DeltaSegment::new(m.dim),
            sealed,
            dead,
            locate,
        };
        Ok((state, m))
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Last committed (or swapped-in) generation number.
    pub fn generation(&self) -> u64 {
        self.state.read().unwrap().gen
    }

    /// Live rows in the delta (compaction-pressure signal).
    pub fn delta_live(&self) -> usize {
        self.state.read().unwrap().delta.live()
    }

    /// Masked sealed rows (tombstone-debt signal).
    pub fn tombstone_count(&self) -> usize {
        self.state.read().unwrap().tombstones()
    }

    /// How the current generation's sealed segments were opened:
    /// `(mapped, copied)` counts, where `mapped` segments serve their
    /// key matrices as borrowed views of the file mapping (zero-copy
    /// v2 containers under `--features mmap`) and `copied` ones
    /// decoded into RAM (legacy v1 containers, misaligned layouts, or
    /// builds without the feature). Exported per tenant by the metrics
    /// listener.
    pub fn segment_open_stats(&self) -> (u64, u64) {
        let st = self.state.read().unwrap();
        let mapped = st.sealed.iter().filter(|s| s.zero_copy()).count() as u64;
        (mapped, st.sealed.len() as u64 - mapped)
    }

    /// Append `vecs` as new rows; returns the assigned global ids
    /// (dense, monotonically increasing, never reused).
    pub fn insert(&self, vecs: &Tensor) -> Result<Vec<u32>> {
        ensure!(vecs.rows() > 0, "insert needs at least one row");
        ensure!(
            vecs.row_width() == self.dim,
            "insert dim {} != collection dim {}",
            vecs.row_width(),
            self.dim
        );
        let _m = self.mutate.lock().unwrap();
        let mut st = self.state.write().unwrap();
        ensure!(
            (st.next_id as u64) + (vecs.rows() as u64) <= u32::MAX as u64,
            "id space exhausted"
        );
        let mut out = Vec::with_capacity(vecs.rows());
        for r in 0..vecs.rows() {
            let gid = st.next_id;
            st.next_id += 1;
            let row = st.delta.push(gid, vecs.row(r));
            st.locate.insert(gid, Loc::Delta(row));
            out.push(gid);
        }
        Ok(out)
    }

    /// Replace (or create) the rows at `ids`; `ids[i]` gets `vecs`
    /// row `i`. Later duplicates within one call win.
    pub fn upsert(&self, ids: &[u32], vecs: &Tensor) -> Result<()> {
        ensure!(
            ids.len() == vecs.rows(),
            "upsert got {} ids for {} rows",
            ids.len(),
            vecs.rows()
        );
        ensure!(!ids.is_empty(), "upsert needs at least one row");
        ensure!(
            vecs.row_width() == self.dim,
            "upsert dim {} != collection dim {}",
            vecs.row_width(),
            self.dim
        );
        let _m = self.mutate.lock().unwrap();
        let mut st = self.state.write().unwrap();
        for (r, &gid) in ids.iter().enumerate() {
            ensure!(gid < u32::MAX, "id {gid} is reserved");
            if let Some(loc) = st.locate.remove(&gid) {
                Self::kill(&mut st, loc);
            }
            if gid >= st.next_id {
                st.next_id = gid + 1;
            }
            let row = st.delta.push(gid, vecs.row(r));
            st.locate.insert(gid, Loc::Delta(row));
        }
        Ok(())
    }

    /// Remove rows by id; unknown/already-deleted ids are ignored.
    /// Returns how many rows were actually removed.
    pub fn delete(&self, ids: &[u32]) -> Result<usize> {
        let _m = self.mutate.lock().unwrap();
        let mut st = self.state.write().unwrap();
        let mut removed = 0;
        for gid in ids {
            if let Some(loc) = st.locate.remove(gid) {
                Self::kill(&mut st, loc);
                removed += 1;
            }
        }
        Ok(removed)
    }

    fn kill(st: &mut State, loc: Loc) {
        match loc {
            Loc::Delta(row) => st.delta.kill(row),
            Loc::Sealed(si, lid) => {
                st.dead[si].insert(lid);
            }
        }
    }

    /// Fan-out search: every sealed segment is over-fetched by its
    /// tombstone count (so ≥ k live candidates survive masking — this
    /// is what keeps `Exhaustive` exact under churn), remapped to
    /// global ids and merged with the delta scan in one shared top-k.
    fn search_state(&self, st: &State, query: &[f32], k: usize, effort: Effort) -> SearchResult {
        let k = k.max(1);
        let mut top = TopK::new(k);
        let mut cost = SearchCost::default();
        for (si, seg) in st.sealed.iter().enumerate() {
            let dead = &st.dead[si];
            let kk = k.saturating_add(dead.len()).min(seg.len());
            if kk == 0 {
                continue;
            }
            let res = seg.search_local(query, kk, effort);
            cost.add(res.cost);
            for (j, &lid) in res.ids.iter().enumerate() {
                if !dead.contains(&lid) {
                    top.offer(res.scores[j], seg.ids()[lid as usize]);
                }
            }
        }
        cost.add(st.delta.scan(query, &mut top));
        let (ids, scores) = top.into_sorted();
        SearchResult { ids, scores, cost }
    }

    /// Seal the delta (if non-empty) as a new flat segment and commit
    /// a new generation recording current segments + tombstones.
    /// Cheap: no index rebuild. Returns the new generation number.
    pub fn commit(&self) -> Result<u64> {
        let _m = self.mutate.lock().unwrap();
        self.commit_locked()
    }

    fn commit_locked(&self) -> Result<u64> {
        // Snapshot under a read lock; the mutate mutex (held by our
        // caller) guarantees nothing changes until we swap.
        let (gen, next_id, mut segments, tombstones, gathered) = {
            let st = self.state.read().unwrap();
            let segments: Vec<String> =
                st.sealed.iter().map(|s| s.file().to_string()).collect();
            let mut tombstones = Vec::new();
            for (si, dead) in st.dead.iter().enumerate() {
                let mut lids: Vec<u32> = dead.iter().copied().collect();
                lids.sort_unstable();
                for lid in lids {
                    tombstones.push((st.sealed[si].file().to_string(), lid));
                }
            }
            (st.gen, st.next_id, segments, tombstones, st.delta.gather_sorted())
        };
        let new_gen = gen + 1;
        let mut new_seg = None;
        if let Some((ids, keys)) = gathered {
            let file = SealedSegment::file_name(new_gen, segments.len());
            let path = self.dir.join(&file);
            SealedSegment::write(&path, &ids, &keys, None)?;
            // reload through the validating (and mmap-aware) path
            new_seg = Some(Arc::new(SealedSegment::load(&path)?));
            segments.push(file);
        }
        GenManifest {
            gen: new_gen,
            dim: self.dim,
            seed: self.seed,
            next_id,
            segments,
            tombstones,
        }
        .write(&self.dir)?;
        {
            let mut st = self.state.write().unwrap();
            st.gen = new_gen;
            if let Some(seg) = new_seg {
                let si = st.sealed.len();
                for (lid, &gid) in seg.ids().iter().enumerate() {
                    st.locate.insert(gid, Loc::Sealed(si, lid as u32));
                }
                st.sealed.push(seg);
                st.dead.push(HashSet::new());
                st.delta = DeltaSegment::new(self.dim);
            }
        }
        self.gc(new_gen);
        Ok(new_gen)
    }

    /// Fold delta + all sealed segments + tombstones into one fresh
    /// sealed segment built through [`IndexSpec::build`], then commit.
    /// The expensive build runs without the state write lock — old
    /// generation serves until the O(1) swap. Returns the new
    /// generation number.
    pub fn compact(&self) -> Result<u64> {
        let _m = self.mutate.lock().unwrap();
        let (gen, next_id, mut live) = {
            let st = self.state.read().unwrap();
            let mut live: Vec<(u32, Vec<f32>)> = Vec::with_capacity(st.live_len());
            for (si, seg) in st.sealed.iter().enumerate() {
                for (lid, &gid) in seg.ids().iter().enumerate() {
                    if !st.dead[si].contains(&(lid as u32)) {
                        live.push((gid, seg.keys().row(lid).to_vec()));
                    }
                }
            }
            for r in 0..st.delta.rows() {
                if st.delta.is_alive(r) {
                    live.push((st.delta.id_of(r), st.delta.row(r).to_vec()));
                }
            }
            (st.gen, st.next_id, live)
        };
        live.sort_unstable_by_key(|(gid, _)| *gid);
        let new_gen = gen + 1;
        let mut segments = Vec::new();
        let mut new_seg = None;
        if !live.is_empty() {
            let ids: Vec<u32> = live.iter().map(|(gid, _)| *gid).collect();
            let mut data = Vec::with_capacity(live.len() * self.dim);
            for (_, row) in &live {
                data.extend_from_slice(row);
            }
            let keys = Tensor::from_vec(&[live.len(), self.dim], data);
            // flat segments are served by direct scan over the raw
            // keys — embedding a flat artifact would store them twice
            let built = match self.spec {
                IndexSpec::Flat(_) => None,
                _ => Some(
                    self.spec
                        .build(&keys, &BuildCtx::seeded(self.seed ^ new_gen))?,
                ),
            };
            let file = SealedSegment::file_name(new_gen, 0);
            let path = self.dir.join(&file);
            SealedSegment::write(&path, &ids, &keys, built.as_deref())?;
            new_seg = Some(Arc::new(SealedSegment::load(&path)?));
            segments.push(file);
        }
        GenManifest {
            gen: new_gen,
            dim: self.dim,
            seed: self.seed,
            next_id,
            segments,
            tombstones: Vec::new(),
        }
        .write(&self.dir)?;
        {
            let mut st = self.state.write().unwrap();
            st.gen = new_gen;
            st.sealed.clear();
            st.dead.clear();
            st.locate.clear();
            if let Some(seg) = new_seg {
                for (lid, &gid) in seg.ids().iter().enumerate() {
                    st.locate.insert(gid, Loc::Sealed(0, lid as u32));
                }
                st.sealed.push(seg);
                st.dead.push(HashSet::new());
            }
            st.delta = DeltaSegment::new(self.dim);
        }
        self.gc(new_gen);
        Ok(new_gen)
    }

    /// Best-effort cleanup after a commit: keep the two newest valid
    /// generations (current + one fallback) and every segment they
    /// reference; drop older manifests, unreferenced segments, torn
    /// `.tmp` files and any poison manifest claiming a future
    /// generation. Failures are ignored — GC never blocks a commit.
    fn gc(&self, newest: u64) {
        let Ok(gens) = manifest::list_generations(&self.dir) else {
            return;
        };
        let mut keep_gens: HashSet<u64> = HashSet::new();
        let mut keep_files: HashSet<String> = HashSet::new();
        for (g, path) in &gens {
            if keep_gens.len() >= 2 || *g > newest {
                continue;
            }
            if let Ok(m) = GenManifest::read(path) {
                keep_gens.insert(*g);
                keep_files.extend(m.segments.iter().cloned());
            }
        }
        let Ok(rd) = std::fs::read_dir(&self.dir) else {
            return;
        };
        for entry in rd.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let junk = if name.ends_with(".tmp") {
                true
            } else if let Some(g) = manifest::parse_gen_file_name(name) {
                !keep_gens.contains(&g)
            } else if name.starts_with("seg-") && name.ends_with(".ams") {
                !keep_files.contains(name)
            } else {
                false
            };
            if junk {
                let _ = std::fs::remove_file(entry.path());
            }
        }
    }
}

impl VectorIndex for MutableCollection {
    fn name(&self) -> &str {
        "mutable"
    }

    /// Live rows (inserted minus deleted), across delta + sealed.
    fn len(&self) -> usize {
        self.state.read().unwrap().live_len()
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn n_cells(&self) -> usize {
        let st = self.state.read().unwrap();
        st.sealed
            .iter()
            .map(|s| s.index().n_cells())
            .max()
            .unwrap_or(1)
            .max(1)
    }

    fn search_effort(&self, query: &[f32], k: usize, effort: Effort) -> SearchResult {
        let st = self.state.read().unwrap();
        self.search_state(&st, query, k, effort)
    }

    /// The spec future compactions build with (not necessarily what
    /// every current segment was built with).
    fn spec(&self) -> IndexSpec {
        self.spec.clone()
    }

    fn write_payload(&self, _w: &mut Vec<u8>) -> Result<()> {
        bail!(
            "mutable collections persist as generation manifests (gen-*.tsv), \
             not monolithic artifacts; use commit()/compact()"
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{Rng, TempDir};

    fn rows(n: usize, d: usize, seed: u64) -> Tensor {
        let mut t = Tensor::zeros(&[n, d]);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        crate::tensor::normalize_rows(&mut t);
        t
    }

    fn flat() -> IndexSpec {
        IndexSpec::default_for("flat").unwrap()
    }

    #[test]
    fn create_refuses_reinit_and_open_recovers() {
        let tmp = TempDir::new("mcoll");
        let dir = tmp.join("c.seg");
        let c = MutableCollection::create(&dir, flat(), 8, 1).unwrap();
        assert!(MutableCollection::create(&dir, flat(), 8, 1).is_err());
        let ids = c.insert(&rows(10, 8, 2)).unwrap();
        assert_eq!(ids, (0..10).collect::<Vec<u32>>());
        assert_eq!(c.len(), 10);
        // unsynced mutations are RAM-only: reopen sees generation 0
        let again = MutableCollection::open(&dir, flat()).unwrap();
        assert_eq!((again.len(), again.generation()), (0, 0));
        // after commit, reopen sees everything
        c.commit().unwrap();
        let again = MutableCollection::open(&dir, flat()).unwrap();
        assert_eq!((again.len(), again.generation()), (10, 1));
    }

    #[test]
    fn insert_delete_upsert_search_lifecycle() {
        let tmp = TempDir::new("mcoll");
        let c = MutableCollection::create(&tmp.join("c.seg"), flat(), 4, 1).unwrap();
        c.insert(&rows(20, 4, 3)).unwrap();
        c.commit().unwrap(); // rows now sealed
        assert_eq!(c.delete(&[3, 7, 3, 999]).unwrap(), 2);
        assert_eq!(c.len(), 18);
        assert_eq!(c.tombstone_count(), 2);
        let q = rows(1, 4, 4);
        let res = c.search_effort(q.row(0), 20, Effort::Exhaustive);
        assert_eq!(res.ids.len(), 18);
        assert!(!res.ids.contains(&3) && !res.ids.contains(&7));
        // upsert resurrects a deleted id with a fresh vector
        c.upsert(&[3], &rows(1, 4, 5)).unwrap();
        assert_eq!(c.len(), 19);
        let res = c.search_effort(q.row(0), 30, Effort::Exhaustive);
        assert!(res.ids.contains(&3));
        // upsert past the end mints ids
        c.upsert(&[40], &rows(1, 4, 6)).unwrap();
        let ids = c.insert(&rows(1, 4, 7)).unwrap();
        assert_eq!(ids, vec![41]);
    }

    #[test]
    fn compact_preserves_results_and_gcs_old_files() {
        let tmp = TempDir::new("mcoll");
        let dir = tmp.join("c.seg");
        let c = MutableCollection::create(&dir, flat(), 8, 1).unwrap();
        c.insert(&rows(50, 8, 2)).unwrap();
        c.commit().unwrap();
        c.delete(&(0..10).collect::<Vec<u32>>()).unwrap();
        c.insert(&rows(5, 8, 3)).unwrap();
        let q = rows(3, 8, 4);
        let before: Vec<SearchResult> = (0..3)
            .map(|i| c.search_effort(q.row(i), 12, Effort::Exhaustive))
            .collect();
        let gen = c.compact().unwrap();
        assert_eq!(c.generation(), gen);
        assert_eq!(c.tombstone_count(), 0);
        for (i, want) in before.iter().enumerate() {
            let got = c.search_effort(q.row(i), 12, Effort::Exhaustive);
            assert_eq!(got.ids, want.ids, "query {i}");
            assert_eq!(got.scores, want.scores, "query {i}");
        }
        // reopen from disk: same story
        let again = MutableCollection::open(&dir, flat()).unwrap();
        for (i, want) in before.iter().enumerate() {
            let got = again.search_effort(q.row(i), 12, Effort::Exhaustive);
            assert_eq!(got.ids, want.ids, "reopened query {i}");
            assert_eq!(got.scores, want.scores, "reopened query {i}");
        }
        // GC keeps at most two generations' worth of files around
        let gens = manifest::list_generations(&dir).unwrap();
        assert!(gens.len() <= 2, "gc left {} manifests", gens.len());
    }

    #[test]
    fn compact_empty_collection_is_fine() {
        let tmp = TempDir::new("mcoll");
        let c = MutableCollection::create(&tmp.join("c.seg"), flat(), 4, 1).unwrap();
        let gen = c.compact().unwrap();
        assert_eq!(gen, 1);
        assert_eq!(c.len(), 0);
        let ids = c.insert(&rows(2, 4, 2)).unwrap();
        assert_eq!(ids, vec![0, 1]);
        // delete everything, compact down to zero segments
        c.delete(&ids).unwrap();
        c.compact().unwrap();
        let again = MutableCollection::open(&c.dir().to_path_buf(), flat()).unwrap();
        assert_eq!(again.len(), 0);
        // ids are never reused even across an empty compaction
        assert_eq!(again.insert(&rows(1, 4, 3)).unwrap(), vec![2]);
    }

    #[test]
    fn rejects_shape_mismatches() {
        let tmp = TempDir::new("mcoll");
        let c = MutableCollection::create(&tmp.join("c.seg"), flat(), 4, 1).unwrap();
        assert!(c.insert(&rows(1, 5, 2)).is_err());
        assert!(c.insert(&Tensor::zeros(&[0, 4])).is_err());
        assert!(c.upsert(&[0, 1], &rows(1, 4, 2)).is_err());
        assert!(c.upsert(&[], &Tensor::zeros(&[0, 4])).is_err());
    }
}
