//! The delta segment: an append-friendly in-RAM buffer holding every
//! row written since the last commit, searched by exact flat scan.
//!
//! Rows are appended in arrival order and never moved; deletes and
//! upserts mark the old row dead in place (`alive` bitmap), so a row
//! index handed out by [`DeltaSegment::push`] stays valid for the
//! lifetime of the delta. Sealing gathers the live rows *sorted by
//! global id* (restoring the strictly-increasing id-map invariant
//! sealed segments rely on) and the delta starts over empty.

use crate::index::traits::{SearchCost, TopK};
use crate::tensor::{dot, Tensor};

/// In-RAM segment of recent writes. Not `Sync` by itself — the owning
/// collection guards it with its state lock.
pub struct DeltaSegment {
    dim: usize,
    data: Vec<f32>, // rows * dim, dead rows kept in place
    ids: Vec<u32>,  // global id per row (dead rows keep theirs)
    alive: Vec<bool>,
    live: usize,
}

impl DeltaSegment {
    pub fn new(dim: usize) -> DeltaSegment {
        assert!(dim > 0, "delta segment dim must be positive");
        DeltaSegment {
            dim,
            data: Vec::new(),
            ids: Vec::new(),
            alive: Vec::new(),
            live: 0,
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Total rows ever appended since the last seal, dead included.
    pub fn rows(&self) -> usize {
        self.ids.len()
    }

    /// Rows that are still visible to search.
    pub fn live(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Append one row; returns its delta-row index.
    pub fn push(&mut self, gid: u32, row: &[f32]) -> usize {
        assert_eq!(row.len(), self.dim, "delta row width {} != dim {}", row.len(), self.dim);
        let r = self.ids.len();
        self.data.extend_from_slice(row);
        self.ids.push(gid);
        self.alive.push(true);
        self.live += 1;
        r
    }

    /// Mark a row dead (idempotent).
    pub fn kill(&mut self, row: usize) {
        if self.alive[row] {
            self.alive[row] = false;
            self.live -= 1;
        }
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.dim..(r + 1) * self.dim]
    }

    pub fn id_of(&self, r: usize) -> u32 {
        self.ids[r]
    }

    pub fn is_alive(&self, r: usize) -> bool {
        self.alive[r]
    }

    /// Exact scan over live rows, offering global ids into the shared
    /// top-k. Costs mirror [`crate::index::flat::FlatIndex`]: two
    /// flops per scanned dim, dead rows skipped without scoring.
    pub fn scan(&self, query: &[f32], top: &mut TopK) -> SearchCost {
        let mut scanned = 0u64;
        for r in 0..self.rows() {
            if !self.alive[r] {
                continue;
            }
            top.offer(dot(query, self.row(r)), self.ids[r]);
            scanned += 1;
        }
        SearchCost {
            flops: scanned * self.dim as u64 * 2,
            keys_scanned: scanned,
            cells_probed: 0,
        }
    }

    /// Gather the live rows sorted by global id: the `(ids, keys)`
    /// pair a sealed segment is written from. Returns `None` when no
    /// row is live.
    pub fn gather_sorted(&self) -> Option<(Vec<u32>, Tensor)> {
        if self.live == 0 {
            return None;
        }
        let mut order: Vec<usize> = (0..self.rows()).filter(|&r| self.alive[r]).collect();
        order.sort_by_key(|&r| self.ids[r]);
        let mut ids = Vec::with_capacity(order.len());
        let mut data = Vec::with_capacity(order.len() * self.dim);
        for &r in &order {
            ids.push(self.ids[r]);
            data.extend_from_slice(self.row(r));
        }
        let keys = Tensor::from_vec(&[order.len(), self.dim], data);
        Some((ids, keys))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Effort;
    use crate::index::flat::FlatIndex;
    use crate::index::traits::VectorIndex;
    use crate::util::Rng;

    fn row(seed: u64, d: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; d];
        Rng::new(seed).fill_normal(&mut v, 1.0);
        v
    }

    #[test]
    fn push_kill_and_live_counts() {
        let mut delta = DeltaSegment::new(4);
        let a = delta.push(10, &row(1, 4));
        let b = delta.push(11, &row(2, 4));
        assert_eq!((delta.rows(), delta.live()), (2, 2));
        delta.kill(a);
        delta.kill(a); // idempotent
        assert_eq!((delta.rows(), delta.live()), (2, 1));
        assert!(!delta.is_alive(a));
        assert!(delta.is_alive(b));
        assert_eq!(delta.id_of(b), 11);
    }

    #[test]
    fn scan_matches_flat_over_live_rows() {
        let d = 8;
        let mut delta = DeltaSegment::new(d);
        let mut live = Vec::new();
        for i in 0..30u64 {
            let r = delta.push(100 + i as u32, &row(i, d));
            if i % 3 == 0 {
                delta.kill(r);
            } else {
                live.push((100 + i as u32, row(i, d)));
            }
        }
        let q = row(99, d);
        let mut top = TopK::new(5);
        let cost = delta.scan(&q, &mut top);
        let (got_ids, got_scores) = top.into_sorted();
        assert_eq!(cost.keys_scanned, live.len() as u64);

        let mut data = Vec::new();
        for (_, v) in &live {
            data.extend_from_slice(v);
        }
        let flat = FlatIndex::new(Tensor::from_vec(&[live.len(), d], data));
        let want = flat.search_effort(&q, 5, Effort::Exhaustive);
        let want_ids: Vec<u32> = want.ids.iter().map(|&i| live[i as usize].0).collect();
        assert_eq!(got_ids, want_ids);
        assert_eq!(got_scores, want.scores);
    }

    #[test]
    fn gather_sorted_restores_monotone_ids() {
        let mut delta = DeltaSegment::new(2);
        delta.push(5, &[1.0, 0.0]);
        let dead = delta.push(1, &[0.0, 1.0]);
        delta.push(3, &[0.5, 0.5]);
        delta.kill(dead);
        // arrival order deliberately disagrees with id order
        delta.push(2, &[0.25, 0.75]);
        let (ids, keys) = delta.gather_sorted().unwrap();
        assert_eq!(ids, vec![2, 3, 5]);
        assert_eq!(keys.rows(), 3);
        assert_eq!(keys.row(2), &[1.0, 0.0][..]);
        let empty = DeltaSegment::new(2);
        assert!(empty.gather_sorted().is_none());
    }
}
