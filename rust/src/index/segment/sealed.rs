//! Sealed segments: the immutable on-disk unit of a mutable
//! collection (`seg-<gen>-<i>.ams`).
//!
//! A sealed segment is a checksummed container (magic `AMSG`) holding
//! three things:
//!
//! 1. the local-row → global-id map (strictly increasing, so the
//!    per-backbone tie-break toward lower local id maps exactly onto
//!    the collection-wide tie-break toward lower global id),
//! 2. the raw key vectors — the source of truth future compactions
//!    rebuild from (lossy backbones like PQ cannot reproduce them),
//! 3. optionally an embedded AMIX artifact for any backbone; when
//!    absent the segment is served by an exact flat scan over the raw
//!    keys (the common case for freshly sealed deltas).
//!
//! Container version 2 is the *aligned* layout: a separately
//! checksummed fixed header, a self-describing pad that places the
//! payload base on a 64-byte file offset, and 64-byte-aligned,
//! length-prefixed sections for the id map, the key matrix and the
//! embedded artifact. Loaded through an `Arc<`[`Mapped`]`>`, those
//! sections come back as borrowed views — the scan kernels read key
//! bytes straight from the page cache, and opening a segment faults in
//! pages only as searches touch them. For that reason a *mapped* v2
//! load verifies the header checksum eagerly but skips the full-payload
//! checksum (verifying it would fault in every page and make open
//! O(corpus) again); byte-stream loads and version-1 files verify in
//! full, exactly as before. Version-1 segments still load bit-
//! identically through the decode-into-RAM path (with a one-line note
//! when that happens under a real mapping).
//!
//! Files are written to a `.tmp` sibling and renamed into place, and
//! are only ever referenced by a generation manifest *after* the
//! rename — so a crash mid-write leaves an orphan the loader never
//! trusts and the next commit garbage-collects.

use std::io::Write;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::api::Effort;
use crate::index::artifact::{
    self, fnv1a64, r_tensor, r_u8s, r_u32s, r_u64, w_u64, Src,
};
use crate::index::flat::FlatIndex;
use crate::index::traits::{SearchResult, VectorIndex};
use crate::tensor::mapped::{stats, Section};
use crate::tensor::{Mapped, Tensor};

/// Magic bytes of the sealed-segment container.
pub const SEG_MAGIC: &[u8; 4] = b"AMSG";
/// Container version this build writes (and the newest it reads).
pub const SEG_VERSION: u32 = 2;
/// Oldest container version this build still reads.
pub const SEG_MIN_VERSION: u32 = 1;
/// Same implausibility cap as the AMIX container.
const MAX_ELEMS: u64 = 1 << 31;
/// Byte length of the fixed, separately checksummed v2 header prefix:
/// magic + version + dim + len + plen.
const V2_HEAD: usize = 4 + 4 + 8 + 8 + 8;

enum Body {
    /// No embedded artifact: serve by exact flat scan over raw keys.
    Flat(FlatIndex),
    /// Embedded backbone artifact + the raw keys it was built from.
    Backbone {
        keys: Tensor,
        index: Box<dyn VectorIndex>,
    },
}

/// One immutable, loaded (or mapped) segment of a mutable collection.
pub struct SealedSegment {
    file: String,
    ids: Section<u32>,
    body: Body,
}

impl SealedSegment {
    /// Canonical file name: generation that sealed it + ordinal within
    /// that generation.
    pub fn file_name(gen: u64, ordinal: usize) -> String {
        format!("seg-{gen:06}-{ordinal}.ams")
    }

    /// Number of rows (dead or alive — tombstones live outside).
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.keys().row_width()
    }

    /// File name within the collection directory.
    pub fn file(&self) -> &str {
        &self.file
    }

    /// Local row → global id map (strictly increasing).
    pub fn ids(&self) -> &[u32] {
        &self.ids
    }

    /// Raw key vectors, `[len, dim]`.
    pub fn keys(&self) -> &Tensor {
        match &self.body {
            Body::Flat(f) => f.keys(),
            Body::Backbone { keys, .. } => keys,
        }
    }

    /// The serving index (flat scan or the embedded backbone).
    pub fn index(&self) -> &dyn VectorIndex {
        match &self.body {
            Body::Flat(f) => f,
            Body::Backbone { index, .. } => index.as_ref(),
        }
    }

    /// Whether this segment serves its key matrix as a borrowed view
    /// of the file mapping (zero-copy) rather than a decoded RAM copy.
    pub fn zero_copy(&self) -> bool {
        self.keys().is_view()
    }

    /// Top-k in *local* row ids; the collection remaps through
    /// [`SealedSegment::ids`] and masks tombstones.
    pub fn search_local(&self, query: &[f32], k: usize, effort: Effort) -> SearchResult {
        self.index().search_effort(query, k, effort)
    }

    /// Serialize `ids` + raw `keys` (+ optionally a backbone artifact
    /// built over those keys) in the aligned v2 layout and commit via
    /// write-then-rename.
    pub fn write(
        path: &Path,
        ids: &[u32],
        keys: &Tensor,
        index: Option<&dyn VectorIndex>,
    ) -> Result<()> {
        ensure!(
            ids.len() == keys.rows(),
            "sealed segment id map covers {} rows but keys have {}",
            ids.len(),
            keys.rows()
        );
        ensure!(!ids.is_empty(), "refusing to seal an empty segment");
        ensure!(
            ids.windows(2).all(|w| w[0] < w[1]),
            "sealed segment ids must be strictly increasing"
        );
        // The payload base lands on a 64-byte file offset (see below),
        // so payload-relative section alignment is file alignment.
        let mut payload = Vec::new();
        artifact::w_section_u32s(&mut payload, ids)?;
        artifact::w_tensor_v3(&mut payload, keys)?;
        let mut art = Vec::new();
        if let Some(index) = index {
            ensure!(
                index.len() == keys.rows() && index.dim() == keys.row_width(),
                "embedded index shape {}x{} disagrees with keys {}x{}",
                index.len(),
                index.dim(),
                keys.rows(),
                keys.row_width()
            );
            index.save(&mut art)?;
        }
        // Align the embedded artifact's frame start: its own header pad
        // then places the inner payload on a 64-byte file offset too,
        // so the backbone's sections map zero-copy as well.
        w_u64(&mut payload, art.len() as u64)?;
        artifact::w_align(&mut payload)?;
        payload.write_all(&art)?;

        let tmp = path.with_extension("ams.tmp");
        let mut bytes = Vec::with_capacity(payload.len() + 128);
        bytes.write_all(SEG_MAGIC)?;
        artifact::w_u32(&mut bytes, SEG_VERSION)?;
        w_u64(&mut bytes, keys.row_width() as u64)?;
        w_u64(&mut bytes, keys.rows() as u64)?;
        w_u64(&mut bytes, payload.len() as u64)?;
        debug_assert_eq!(bytes.len(), V2_HEAD);
        // the fixed header gets its own checksum so a lazy (mapped)
        // open can validate everything it trusts without touching the
        // payload pages
        w_u64(&mut bytes, fnv1a64(&bytes[..V2_HEAD]))?;
        artifact::w_align(&mut bytes)?;
        debug_assert_eq!(bytes.len() % artifact::SECTION_ALIGN, 0);
        bytes.write_all(&payload)?;
        w_u64(&mut bytes, fnv1a64(&payload))?;
        std::fs::write(&tmp, &bytes).with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, path).with_context(|| format!("sealing {}", path.display()))?;
        Ok(())
    }

    /// Load (mmap under the `mmap` feature) + validate one segment
    /// file. Every structural claim is checked before use: magic /
    /// version, checksums (see the module doc for what a lazy mapped
    /// open verifies), id-map monotonicity, shape agreement between
    /// header, keys and any embedded artifact.
    pub fn load(path: &Path) -> Result<SealedSegment> {
        let file = path
            .file_name()
            .and_then(|n| n.to_str())
            .context("segment path has no file name")?
            .to_string();
        let mapped = Arc::new(
            Mapped::open(path)
                .with_context(|| format!("opening sealed segment {}", path.display()))?,
        );
        Self::decode(&mapped, file)
            .with_context(|| format!("loading sealed segment {}", path.display()))
    }

    /// Decode a segment container from a shared mapping (or RAM
    /// buffer). Exposed to the collection layer so lazy opens can
    /// reuse an already-open mapping.
    pub(crate) fn decode(map: &Arc<Mapped>, file: String) -> Result<SealedSegment> {
        let bytes = map.as_slice();
        ensure!(bytes.len() >= 8, "sealed segment truncated before version");
        ensure!(
            &bytes[..4] == SEG_MAGIC,
            "bad sealed segment magic {:?} (expected {SEG_MAGIC:?})",
            &bytes[..4]
        );
        let version = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]);
        match version {
            1 => {
                if map.is_map() {
                    eprintln!(
                        "amips: {file}: legacy v1 sealed segment under mmap — decoding by \
                         copy (recompact to get the zero-copy v{SEG_VERSION} layout)"
                    );
                    stats::add_copied(bytes.len() as u64);
                }
                Self::decode_v1(bytes, file)
            }
            2 => Self::decode_v2(map, file),
            other => bail!(
                "unsupported sealed segment version {other} \
                 (this build reads versions {SEG_MIN_VERSION}..={SEG_VERSION})"
            ),
        }
    }

    /// The aligned v2 layout: header + header checksum, pad, aligned
    /// payload sections, payload checksum.
    fn decode_v2(map: &Arc<Mapped>, file: String) -> Result<SealedSegment> {
        let bytes = map.as_slice();
        let mut src = Src::mapped(bytes, map);
        let mut magic = [0u8; 4];
        std::io::Read::read_exact(&mut src, &mut magic).context("reading segment magic")?;
        let _version = artifact::r_u32(&mut src)?;
        let dim = r_u64(&mut src)?;
        let len = r_u64(&mut src)?;
        ensure!(
            dim > 0 && dim <= MAX_ELEMS && len > 0 && len <= MAX_ELEMS,
            "implausible sealed segment shape {len}x{dim}"
        );
        let plen = r_u64(&mut src)?;
        ensure!(
            plen <= bytes.len() as u64,
            "sealed segment truncated: payload claims {plen} bytes of a {}-byte file",
            bytes.len()
        );
        let want_head = r_u64(&mut src).context("sealed segment truncated: missing header checksum")?;
        let got_head = fnv1a64(&bytes[..V2_HEAD]);
        ensure!(
            got_head == want_head,
            "sealed segment header checksum mismatch (stored {want_head:#018x}, computed {got_head:#018x}): corrupt file"
        );
        let pad = artifact::r_u32(&mut src)? as usize;
        ensure!(
            pad < artifact::SECTION_ALIGN,
            "implausible sealed segment header pad {pad}"
        );
        src.take(pad).context("sealed segment truncated inside header pad")?;
        let payload = src
            .take(plen as usize)
            .context("sealed segment truncated inside payload")?;
        let want = r_u64(&mut src).context("sealed segment truncated: missing checksum")?;
        ensure!(
            src.is_empty(),
            "sealed segment has {} trailing bytes after checksum",
            src.remaining()
        );
        // Lazy open: on a real mapping the payload checksum is skipped
        // (it would fault in every page); the header checksum above and
        // the structural checks below still gate everything we trust.
        if !map.is_map() {
            let got = fnv1a64(payload);
            ensure!(
                got == want,
                "sealed segment checksum mismatch (stored {want:#018x}, computed {got:#018x}): corrupt file"
            );
        }

        let mut p = Src::mapped(payload, map);
        let ids: Section<u32> = artifact::r_section(&mut p)?;
        let keys = artifact::r_tensor_v3(&mut p)?;
        let art_len = r_u64(&mut p)?;
        ensure!(
            art_len <= plen,
            "sealed segment embedded artifact claims {art_len} bytes of a {plen}-byte payload"
        );
        artifact::r_align(&mut p)?;
        let art = p
            .take(art_len as usize)
            .context("sealed segment truncated inside embedded artifact")?;
        ensure!(p.is_empty(), "sealed segment payload has trailing bytes");
        Self::assemble(file, ids, keys, art, Some(map), (dim, len))
    }

    /// The legacy v1 layout: one whole-payload checksum, unaligned
    /// fields, always decoded into RAM (bit-identical to the build
    /// that wrote it).
    fn decode_v1(bytes: &[u8], file: String) -> Result<SealedSegment> {
        let mut r: &[u8] = &bytes[8..]; // past magic + version
        let dim = r_u64(&mut r)?;
        let len = r_u64(&mut r)?;
        ensure!(
            dim > 0 && dim <= MAX_ELEMS && len > 0 && len <= MAX_ELEMS,
            "implausible sealed segment shape {len}x{dim}"
        );
        let plen = r_u64(&mut r)?;
        ensure!(
            plen <= r.len() as u64,
            "sealed segment truncated: payload claims {plen} bytes, {} remain",
            r.len()
        );
        let (payload, mut rest) = r.split_at(plen as usize);
        let want = r_u64(&mut rest).context("sealed segment truncated: missing checksum")?;
        let got = fnv1a64(payload);
        ensure!(
            got == want,
            "sealed segment checksum mismatch (stored {want:#018x}, computed {got:#018x}): corrupt file"
        );
        ensure!(
            rest.is_empty(),
            "sealed segment has {} trailing bytes after checksum",
            rest.len()
        );

        let mut p: &[u8] = payload;
        let ids = Section::owned(r_u32s(&mut p)?);
        let keys = r_tensor(&mut p)?;
        let art = r_u8s(&mut p)?;
        ensure!(p.is_empty(), "sealed segment payload has trailing bytes");
        Self::assemble(file, ids, keys, &art, None, (dim, len))
    }

    /// Validation + body assembly shared by both layout decoders.
    fn assemble(
        file: String,
        ids: Section<u32>,
        keys: Tensor,
        art: &[u8],
        map: Option<&Arc<Mapped>>,
        (dim, len): (u64, u64),
    ) -> Result<SealedSegment> {
        ensure!(
            ids.len() as u64 == len && keys.rows() as u64 == len,
            "sealed segment header advertises {len} rows but decodes {} ids over {} keys",
            ids.len(),
            keys.rows()
        );
        ensure!(
            keys.row_width() as u64 == dim,
            "sealed segment header advertises dim {dim} but keys decode to {}",
            keys.row_width()
        );
        ensure!(
            ids.windows(2).all(|w| w[0] < w[1]),
            "sealed segment id map is not strictly increasing: corrupt file"
        );
        keys.advise_sequential();
        let body = if art.is_empty() {
            Body::Flat(FlatIndex::new(keys))
        } else {
            let index = match map {
                Some(map) => artifact::load_from_src(&mut Src::mapped(art, map))?,
                None => artifact::load_from(&mut { art })?,
            };
            if index.len() != keys.rows() || index.dim() != keys.row_width() {
                bail!(
                    "embedded artifact shape {}x{} disagrees with segment keys {}x{}",
                    index.len(),
                    index.dim(),
                    keys.rows(),
                    keys.row_width()
                );
            }
            Body::Backbone { keys, index }
        };
        Ok(SealedSegment { file, ids, body })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::spec::{BuildCtx, IndexSpec};
    use crate::util::{Rng, TempDir};

    fn unit(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        crate::tensor::normalize_rows(&mut t);
        t
    }

    #[test]
    fn flat_round_trip_scans_exactly() {
        let tmp = TempDir::new("sealed");
        let keys = unit(&[64, 8], 1);
        let ids: Vec<u32> = (0..64).map(|i| i * 3).collect();
        let path = tmp.join(&SealedSegment::file_name(1, 0));
        SealedSegment::write(&path, &ids, &keys, None).unwrap();
        let seg = SealedSegment::load(&path).unwrap();
        assert_eq!((seg.len(), seg.dim()), (64, 8));
        assert_eq!(seg.ids(), &ids[..]);
        let q = unit(&[1, 8], 2);
        let want = FlatIndex::new(keys).search_effort(q.row(0), 5, Effort::Exhaustive);
        let got = seg.search_local(q.row(0), 5, Effort::Exhaustive);
        assert_eq!(want.ids, got.ids);
        assert_eq!(want.scores, got.scores);
        assert!(!tmp.join("seg-000001-0.ams.tmp").exists());
    }

    #[test]
    fn backbone_round_trip_preserves_raw_keys() {
        let tmp = TempDir::new("sealed");
        let keys = unit(&[120, 16], 3);
        let ids: Vec<u32> = (0..120).collect();
        let idx = IndexSpec::default_for("ivf")
            .unwrap()
            .with_nlist(4)
            .build(&keys, &BuildCtx::seeded(7))
            .unwrap();
        let path = tmp.join(&SealedSegment::file_name(2, 1));
        SealedSegment::write(&path, &ids, &keys, Some(idx.as_ref())).unwrap();
        let seg = SealedSegment::load(&path).unwrap();
        assert_eq!(seg.index().name(), "ivf");
        assert_eq!(seg.keys().data(), keys.data());
        let q = unit(&[1, 16], 4);
        let want = idx.search_effort(q.row(0), 7, Effort::Exhaustive);
        let got = seg.search_local(q.row(0), 7, Effort::Exhaustive);
        assert_eq!(want.ids, got.ids);
    }

    #[test]
    fn v2_layout_aligns_payload_and_sections() {
        let tmp = TempDir::new("sealed");
        let keys = unit(&[33, 7], 21); // odd shape: pads must adapt
        let ids: Vec<u32> = (0..33).collect();
        let path = tmp.join(&SealedSegment::file_name(3, 0));
        SealedSegment::write(&path, &ids, &keys, None).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..4], SEG_MAGIC);
        assert_eq!(
            u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]),
            SEG_VERSION
        );
        // header checksum covers the fixed prefix
        let stored = u64::from_le_bytes(bytes[V2_HEAD..V2_HEAD + 8].try_into().unwrap());
        assert_eq!(stored, fnv1a64(&bytes[..V2_HEAD]));
        // the pad places the payload base on a 64-byte file offset
        let pad =
            u32::from_le_bytes(bytes[V2_HEAD + 8..V2_HEAD + 12].try_into().unwrap()) as usize;
        let payload_base = V2_HEAD + 8 + 4 + pad;
        assert_eq!(payload_base % artifact::SECTION_ALIGN, 0);
        // and the segment still loads + scans exactly
        let seg = SealedSegment::load(&path).unwrap();
        assert_eq!(seg.keys().data(), keys.data());
    }

    #[test]
    fn hand_framed_v1_stream_loads_bit_identically() {
        // a v1 container framed by hand with the legacy (unaligned)
        // codecs — old segments on disk must keep decoding to exactly
        // the same rows/keys/results as when they were written
        let tmp = TempDir::new("sealed");
        let keys = unit(&[40, 8], 31);
        let ids: Vec<u32> = (0..40).map(|i| i * 2 + 1).collect();
        let mut payload = Vec::new();
        artifact::w_u32s(&mut payload, &ids).unwrap();
        artifact::w_tensor(&mut payload, &keys).unwrap();
        artifact::w_u8s(&mut payload, &[]).unwrap(); // no embedded artifact
        let mut bytes = Vec::new();
        bytes.extend_from_slice(SEG_MAGIC);
        artifact::w_u32(&mut bytes, 1).unwrap();
        w_u64(&mut bytes, 8).unwrap();
        w_u64(&mut bytes, 40).unwrap();
        w_u64(&mut bytes, payload.len() as u64).unwrap();
        bytes.extend_from_slice(&payload);
        w_u64(&mut bytes, fnv1a64(&payload)).unwrap();
        let path = tmp.join("seg-000001-0.ams");
        std::fs::write(&path, &bytes).unwrap();

        let seg = SealedSegment::load(&path).unwrap();
        assert_eq!(seg.ids(), &ids[..]);
        assert_eq!(seg.keys().data(), keys.data());
        assert!(!seg.zero_copy()); // v1 always decodes by copy
        let q = unit(&[1, 8], 32);
        let want = FlatIndex::new(keys).search_effort(q.row(0), 5, Effort::Exhaustive);
        let got = seg.search_local(q.row(0), 5, Effort::Exhaustive);
        assert_eq!(want.ids, got.ids);
        assert_eq!(
            want.scores.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
            got.scores.iter().map(|s| s.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn unknown_version_is_rejected() {
        let tmp = TempDir::new("sealed");
        let keys = unit(&[8, 4], 33);
        let ids: Vec<u32> = (0..8).collect();
        let path = tmp.join("seg-000001-0.ams");
        SealedSegment::write(&path, &ids, &keys, None).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4] = 0x7F;
        std::fs::write(&path, &bytes).unwrap();
        let err = SealedSegment::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
    }

    #[test]
    fn rejects_malformed_writes() {
        let tmp = TempDir::new("sealed");
        let keys = unit(&[8, 4], 5);
        let path = tmp.join("seg-000001-0.ams");
        // id count mismatch
        assert!(SealedSegment::write(&path, &[1, 2], &keys, None).is_err());
        // non-monotone ids
        let ids: Vec<u32> = (0..8).rev().collect();
        assert!(SealedSegment::write(&path, &ids, &keys, None).is_err());
        // empty segment
        assert!(SealedSegment::write(&path, &[], &Tensor::zeros(&[0, 4]), None).is_err());
    }

    #[test]
    fn corruption_is_typed_never_trusted() {
        let tmp = TempDir::new("sealed");
        let keys = unit(&[32, 8], 6);
        let ids: Vec<u32> = (0..32).collect();
        let path = tmp.join("seg-000001-0.ams");
        SealedSegment::write(&path, &ids, &keys, None).unwrap();
        let clean = std::fs::read(&path).unwrap();
        let mut rng = Rng::new(9);
        for case in 0..crate::util::prop_cases(120) {
            let mut bytes = clean.clone();
            if case % 3 == 0 {
                bytes.truncate(rng.below(bytes.len()));
            } else {
                let i = rng.below(bytes.len());
                bytes[i] ^= (1 + rng.below(255)) as u8;
            }
            if bytes == clean {
                continue;
            }
            let corrupt = tmp.join("seg-000002-0.ams");
            std::fs::write(&corrupt, &bytes).unwrap();
            match SealedSegment::load(&corrupt) {
                // typed error: the common, expected outcome
                Err(_) => {}
                // a flip the checksums cannot see (e.g. inside the
                // alignment pad zeros) must still produce a
                // structurally valid segment
                Ok(seg) => assert_eq!(seg.len(), seg.ids().len()),
            }
        }
    }

    #[test]
    fn corruption_fuzz_over_backbone_segments() {
        // same fuzz, but with an embedded artifact so the flips also
        // land inside the nested AMIX frame and its aligned sections
        let tmp = TempDir::new("sealed");
        let keys = unit(&[48, 8], 41);
        let ids: Vec<u32> = (0..48).collect();
        let idx = IndexSpec::default_for("ivf")
            .unwrap()
            .with_nlist(4)
            .build(&keys, &BuildCtx::seeded(42))
            .unwrap();
        let path = tmp.join("seg-000001-0.ams");
        SealedSegment::write(&path, &ids, &keys, Some(idx.as_ref())).unwrap();
        let clean = std::fs::read(&path).unwrap();
        let mut rng = Rng::new(43);
        for case in 0..crate::util::prop_cases(120) {
            let mut bytes = clean.clone();
            if case % 3 == 0 {
                bytes.truncate(rng.below(bytes.len()));
            } else {
                let i = rng.below(bytes.len());
                bytes[i] ^= (1 + rng.below(255)) as u8;
            }
            if bytes == clean {
                continue;
            }
            let corrupt = tmp.join("seg-000002-0.ams");
            std::fs::write(&corrupt, &bytes).unwrap();
            match SealedSegment::load(&corrupt) {
                Err(_) => {}
                Ok(seg) => assert_eq!(seg.len(), seg.ids().len()),
            }
        }
    }
}
