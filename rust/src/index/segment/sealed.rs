//! Sealed segments: the immutable on-disk unit of a mutable
//! collection (`seg-<gen>-<i>.ams`).
//!
//! A sealed segment is a checksummed container (magic `AMSG`) holding
//! three things:
//!
//! 1. the local-row → global-id map (strictly increasing, so the
//!    per-backbone tie-break toward lower local id maps exactly onto
//!    the collection-wide tie-break toward lower global id),
//! 2. the raw key vectors — the source of truth future compactions
//!    rebuild from (lossy backbones like PQ cannot reproduce them),
//! 3. optionally an embedded AMIX artifact for any backbone; when
//!    absent the segment is served by an exact flat scan over the raw
//!    keys (the common case for freshly sealed deltas).
//!
//! Files are written to a `.tmp` sibling and renamed into place, and
//! are only ever referenced by a generation manifest *after* the
//! rename — so a crash mid-write leaves an orphan the loader never
//! trusts and the next commit garbage-collects.

use std::io::Write;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::api::Effort;
use crate::index::artifact::{self, fnv1a64, r_tensor, r_u8s, r_u32s, r_u64, w_tensor, w_u8s, w_u32s, w_u64};
use crate::index::flat::FlatIndex;
use crate::index::traits::{SearchResult, VectorIndex};
use crate::tensor::Tensor;

use super::mapped::Mapped;

/// Magic bytes of the sealed-segment container.
pub const SEG_MAGIC: &[u8; 4] = b"AMSG";
/// Container version this build reads and writes.
pub const SEG_VERSION: u32 = 1;
/// Same implausibility cap as the AMIX container.
const MAX_ELEMS: u64 = 1 << 31;

enum Body {
    /// No embedded artifact: serve by exact flat scan over raw keys.
    Flat(FlatIndex),
    /// Embedded backbone artifact + the raw keys it was built from.
    Backbone {
        keys: Tensor,
        index: Box<dyn VectorIndex>,
    },
}

/// One immutable, loaded (or mapped) segment of a mutable collection.
pub struct SealedSegment {
    file: String,
    ids: Vec<u32>,
    body: Body,
}

impl SealedSegment {
    /// Canonical file name: generation that sealed it + ordinal within
    /// that generation.
    pub fn file_name(gen: u64, ordinal: usize) -> String {
        format!("seg-{gen:06}-{ordinal}.ams")
    }

    /// Number of rows (dead or alive — tombstones live outside).
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.keys().row_width()
    }

    /// File name within the collection directory.
    pub fn file(&self) -> &str {
        &self.file
    }

    /// Local row → global id map (strictly increasing).
    pub fn ids(&self) -> &[u32] {
        &self.ids
    }

    /// Raw key vectors, `[len, dim]`.
    pub fn keys(&self) -> &Tensor {
        match &self.body {
            Body::Flat(f) => f.keys(),
            Body::Backbone { keys, .. } => keys,
        }
    }

    /// The serving index (flat scan or the embedded backbone).
    pub fn index(&self) -> &dyn VectorIndex {
        match &self.body {
            Body::Flat(f) => f,
            Body::Backbone { index, .. } => index.as_ref(),
        }
    }

    /// Top-k in *local* row ids; the collection remaps through
    /// [`SealedSegment::ids`] and masks tombstones.
    pub fn search_local(&self, query: &[f32], k: usize, effort: Effort) -> SearchResult {
        self.index().search_effort(query, k, effort)
    }

    /// Serialize `ids` + raw `keys` (+ optionally a backbone artifact
    /// built over those keys) and commit via write-then-rename.
    pub fn write(
        path: &Path,
        ids: &[u32],
        keys: &Tensor,
        index: Option<&dyn VectorIndex>,
    ) -> Result<()> {
        ensure!(
            ids.len() == keys.rows(),
            "sealed segment id map covers {} rows but keys have {}",
            ids.len(),
            keys.rows()
        );
        ensure!(!ids.is_empty(), "refusing to seal an empty segment");
        ensure!(
            ids.windows(2).all(|w| w[0] < w[1]),
            "sealed segment ids must be strictly increasing"
        );
        let mut payload = Vec::new();
        w_u32s(&mut payload, ids)?;
        w_tensor(&mut payload, keys)?;
        let mut art = Vec::new();
        if let Some(index) = index {
            ensure!(
                index.len() == keys.rows() && index.dim() == keys.row_width(),
                "embedded index shape {}x{} disagrees with keys {}x{}",
                index.len(),
                index.dim(),
                keys.rows(),
                keys.row_width()
            );
            index.save(&mut art)?;
        }
        w_u8s(&mut payload, &art)?;

        let tmp = path.with_extension("ams.tmp");
        let mut bytes = Vec::with_capacity(payload.len() + 64);
        bytes.write_all(SEG_MAGIC)?;
        artifact::w_u32(&mut bytes, SEG_VERSION)?;
        w_u64(&mut bytes, keys.row_width() as u64)?;
        w_u64(&mut bytes, keys.rows() as u64)?;
        w_u64(&mut bytes, payload.len() as u64)?;
        bytes.write_all(&payload)?;
        w_u64(&mut bytes, fnv1a64(&payload))?;
        std::fs::write(&tmp, &bytes).with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, path).with_context(|| format!("sealing {}", path.display()))?;
        Ok(())
    }

    /// Load (mmap under the `mmap` feature) + fully validate one
    /// segment file. Every structural claim is checked before use:
    /// magic/version, checksum over the payload, id-map monotonicity,
    /// shape agreement between header, keys and any embedded artifact.
    pub fn load(path: &Path) -> Result<SealedSegment> {
        let file = path
            .file_name()
            .and_then(|n| n.to_str())
            .context("segment path has no file name")?
            .to_string();
        let mapped = Mapped::open(path)
            .with_context(|| format!("opening sealed segment {}", path.display()))?;
        Self::decode(&mapped, file)
            .with_context(|| format!("loading sealed segment {}", path.display()))
    }

    fn decode(bytes: &[u8], file: String) -> Result<SealedSegment> {
        let mut r: &[u8] = bytes;
        let mut magic = [0u8; 4];
        std::io::Read::read_exact(&mut r, &mut magic).context("reading segment magic")?;
        ensure!(
            &magic == SEG_MAGIC,
            "bad sealed segment magic {magic:?} (expected {SEG_MAGIC:?})"
        );
        let version = artifact::r_u32(&mut r)?;
        ensure!(
            version == SEG_VERSION,
            "unsupported sealed segment version {version} (this build reads {SEG_VERSION})"
        );
        let dim = r_u64(&mut r)?;
        let len = r_u64(&mut r)?;
        ensure!(
            dim > 0 && dim <= MAX_ELEMS && len > 0 && len <= MAX_ELEMS,
            "implausible sealed segment shape {len}x{dim}"
        );
        let plen = r_u64(&mut r)?;
        ensure!(
            plen <= r.len() as u64,
            "sealed segment truncated: payload claims {plen} bytes, {} remain",
            r.len()
        );
        let (payload, mut rest) = r.split_at(plen as usize);
        let want = r_u64(&mut rest).context("sealed segment truncated: missing checksum")?;
        let got = fnv1a64(payload);
        ensure!(
            got == want,
            "sealed segment checksum mismatch (stored {want:#018x}, computed {got:#018x}): corrupt file"
        );
        ensure!(
            rest.is_empty(),
            "sealed segment has {} trailing bytes after checksum",
            rest.len()
        );

        let mut p: &[u8] = payload;
        let ids = r_u32s(&mut p)?;
        let keys = r_tensor(&mut p)?;
        let art = r_u8s(&mut p)?;
        ensure!(p.is_empty(), "sealed segment payload has trailing bytes");
        ensure!(
            ids.len() as u64 == len && keys.rows() as u64 == len,
            "sealed segment header advertises {len} rows but decodes {} ids over {} keys",
            ids.len(),
            keys.rows()
        );
        ensure!(
            keys.row_width() as u64 == dim,
            "sealed segment header advertises dim {dim} but keys decode to {}",
            keys.row_width()
        );
        ensure!(
            ids.windows(2).all(|w| w[0] < w[1]),
            "sealed segment id map is not strictly increasing: corrupt file"
        );
        let body = if art.is_empty() {
            Body::Flat(FlatIndex::new(keys))
        } else {
            let mut ar: &[u8] = &art;
            let index = artifact::load_from(&mut ar)?;
            if index.len() != keys.rows() || index.dim() != keys.row_width() {
                bail!(
                    "embedded artifact shape {}x{} disagrees with segment keys {}x{}",
                    index.len(),
                    index.dim(),
                    keys.rows(),
                    keys.row_width()
                );
            }
            Body::Backbone { keys, index }
        };
        Ok(SealedSegment { file, ids, body })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::spec::{BuildCtx, IndexSpec};
    use crate::util::{Rng, TempDir};

    fn unit(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        crate::tensor::normalize_rows(&mut t);
        t
    }

    #[test]
    fn flat_round_trip_scans_exactly() {
        let tmp = TempDir::new("sealed");
        let keys = unit(&[64, 8], 1);
        let ids: Vec<u32> = (0..64).map(|i| i * 3).collect();
        let path = tmp.join(&SealedSegment::file_name(1, 0));
        SealedSegment::write(&path, &ids, &keys, None).unwrap();
        let seg = SealedSegment::load(&path).unwrap();
        assert_eq!((seg.len(), seg.dim()), (64, 8));
        assert_eq!(seg.ids(), &ids[..]);
        let q = unit(&[1, 8], 2);
        let want = FlatIndex::new(keys).search_effort(q.row(0), 5, Effort::Exhaustive);
        let got = seg.search_local(q.row(0), 5, Effort::Exhaustive);
        assert_eq!(want.ids, got.ids);
        assert_eq!(want.scores, got.scores);
        assert!(!tmp.join("seg-000001-0.ams.tmp").exists());
    }

    #[test]
    fn backbone_round_trip_preserves_raw_keys() {
        let tmp = TempDir::new("sealed");
        let keys = unit(&[120, 16], 3);
        let ids: Vec<u32> = (0..120).collect();
        let idx = IndexSpec::default_for("ivf")
            .unwrap()
            .with_nlist(4)
            .build(&keys, &BuildCtx::seeded(7))
            .unwrap();
        let path = tmp.join(&SealedSegment::file_name(2, 1));
        SealedSegment::write(&path, &ids, &keys, Some(idx.as_ref())).unwrap();
        let seg = SealedSegment::load(&path).unwrap();
        assert_eq!(seg.index().name(), "ivf");
        assert_eq!(seg.keys().data(), keys.data());
        let q = unit(&[1, 16], 4);
        let want = idx.search_effort(q.row(0), 7, Effort::Exhaustive);
        let got = seg.search_local(q.row(0), 7, Effort::Exhaustive);
        assert_eq!(want.ids, got.ids);
    }

    #[test]
    fn rejects_malformed_writes() {
        let tmp = TempDir::new("sealed");
        let keys = unit(&[8, 4], 5);
        let path = tmp.join("seg-000001-0.ams");
        // id count mismatch
        assert!(SealedSegment::write(&path, &[1, 2], &keys, None).is_err());
        // non-monotone ids
        let ids: Vec<u32> = (0..8).rev().collect();
        assert!(SealedSegment::write(&path, &ids, &keys, None).is_err());
        // empty segment
        assert!(SealedSegment::write(&path, &[], &Tensor::zeros(&[0, 4]), None).is_err());
    }

    #[test]
    fn corruption_is_typed_never_trusted() {
        let tmp = TempDir::new("sealed");
        let keys = unit(&[32, 8], 6);
        let ids: Vec<u32> = (0..32).collect();
        let path = tmp.join("seg-000001-0.ams");
        SealedSegment::write(&path, &ids, &keys, None).unwrap();
        let clean = std::fs::read(&path).unwrap();
        let mut rng = Rng::new(9);
        for case in 0..crate::util::prop_cases(120) {
            let mut bytes = clean.clone();
            if case % 3 == 0 {
                bytes.truncate(rng.below(bytes.len()));
            } else {
                let i = rng.below(bytes.len());
                bytes[i] ^= (1 + rng.below(255)) as u8;
            }
            if bytes == clean {
                continue;
            }
            let corrupt = tmp.join("seg-000002-0.ams");
            std::fs::write(&corrupt, &bytes).unwrap();
            match SealedSegment::load(&corrupt) {
                // typed error: the common, expected outcome
                Err(_) => {}
                // a flip the checksum cannot see (e.g. inside the
                // already-verified header echo) must still produce a
                // structurally valid segment
                Ok(seg) => assert_eq!(seg.len(), seg.ids().len()),
            }
        }
    }
}
