//! Mutable collections: a generation/segment lifecycle on top of the
//! immutable backbones.
//!
//! Every backbone in [`crate::index`] is build-once: great for the
//! paper's experiments, useless for a database that churns under a
//! live server. This module layers mutability *around* them instead of
//! inside them, LSM-style:
//!
//! * [`DeltaSegment`] — a small append-friendly in-RAM segment holding
//!   rows inserted (or upserted) since the last commit. Searched by
//!   exact flat scan, so recent writes are always served exactly.
//! * [`SealedSegment`] — an immutable on-disk segment (`seg-*.ams`):
//!   a checksummed container holding the row→global-id map, the raw
//!   key vectors (the source of truth future compactions rebuild
//!   from), and optionally an embedded AMIX artifact for any backbone.
//!   Sealed payloads are memory-mapped under the `mmap` feature and
//!   read into RAM otherwise (see [`mapped`]).
//! * tombstones — per-segment sets of dead local rows. Deletes and
//!   upserts never rewrite a sealed segment; they mask rows at search
//!   time and are folded away by the next compaction.
//! * [`GenManifest`] — `gen-<n>.tsv`, the versioned, FNV-checksummed,
//!   write-then-rename commit record listing the live segments and
//!   tombstones of one generation. Crash at any point recovers to the
//!   last generation whose manifest *and* every listed segment check
//!   out; torn manifests, stale `.tmp` files and orphan segments are
//!   skipped and garbage-collected.
//! * [`MutableCollection`] — the user-facing handle tying it together:
//!   `insert`/`upsert`/`delete` are serialized by an internal mutex,
//!   searches fan out over delta + sealed segments under a read lock
//!   and merge per-segment [`crate::index::traits::TopK`] results with
//!   tombstone masking, and `commit`/`compact` advance the generation.
//!   It implements [`crate::index::VectorIndex`], so the whole serving
//!   stack (tenant workers, TCP front-end, CLI) works unchanged on a
//!   churning collection.
//! * [`Compactor`] — a background worker that watches delta growth and
//!   tombstone debt and folds everything into one fresh sealed segment
//!   through the existing [`crate::index::IndexSpec::build`] path.
//!   Searches are never blocked: the old generation serves until the
//!   new one commits in an O(1) pointer swap.

pub mod collection;
pub mod compact;
pub mod delta;
pub mod manifest;
pub mod sealed;

pub use collection::MutableCollection;
pub use compact::{Compactor, CompactorConfig};
pub use delta::DeltaSegment;
pub use manifest::GenManifest;
// `Mapped` moved down to the tensor layer (PR 10) so `Tensor` itself
// can hold borrowed views; re-exported here for existing callers.
pub use crate::tensor::Mapped;
pub use sealed::SealedSegment;
