//! Byte source for sealed segment files: `mmap(2)` under the `mmap`
//! feature (zero-copy page-cache startup), plain `std::fs::read` into
//! RAM otherwise. No new crates — the mmap path is a two-symbol libc
//! FFI that std already links against on unix.

use std::fs::File;
use std::io::{self, Read};
use std::path::Path;

/// An immutable byte buffer backed either by an anonymous read of the
/// file or (with `--features mmap` on unix) by a private read-only
/// mapping. Deref to `&[u8]` and hand it to the segment decoder.
pub struct Mapped {
    inner: Inner,
}

enum Inner {
    Ram(Vec<u8>),
    #[cfg(all(feature = "mmap", unix))]
    Map(map::MapHandle),
}

impl Mapped {
    /// Read (or map) an entire file. Empty files yield an empty slice
    /// through the RAM path: `mmap` with `len == 0` is EINVAL.
    pub fn open(path: &Path) -> io::Result<Mapped> {
        let mut f = File::open(path)?;
        let len = f.metadata()?.len();
        if len > usize::MAX as u64 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "segment file larger than address space",
            ));
        }
        #[cfg(all(feature = "mmap", unix))]
        {
            if len > 0 {
                match map::MapHandle::map(&f, len as usize) {
                    Ok(m) => return Ok(Mapped { inner: Inner::Map(m) }),
                    // e.g. a filesystem that refuses mappings — fall
                    // back to the portable read-into-RAM path.
                    Err(_) => {}
                }
            }
        }
        let mut buf = Vec::with_capacity(len as usize);
        f.read_to_end(&mut buf)?;
        Ok(Mapped { inner: Inner::Ram(buf) })
    }

    /// Wrap an in-RAM buffer (used by tests and by writers that keep
    /// the bytes they just produced).
    pub fn from_vec(buf: Vec<u8>) -> Mapped {
        Mapped { inner: Inner::Ram(buf) }
    }

    pub fn as_slice(&self) -> &[u8] {
        match &self.inner {
            Inner::Ram(v) => v,
            #[cfg(all(feature = "mmap", unix))]
            Inner::Map(m) => m.as_slice(),
        }
    }

    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl std::ops::Deref for Mapped {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

#[cfg(all(feature = "mmap", unix))]
mod map {
    use std::fs::File;
    use std::io;
    use std::os::unix::io::AsRawFd;

    use core::ffi::{c_int, c_void};

    const PROT_READ: c_int = 1;
    const MAP_PRIVATE: c_int = 2;

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }

    /// A private read-only mapping of one whole file, unmapped on drop.
    pub(super) struct MapHandle {
        ptr: *mut c_void,
        len: usize,
    }

    // The mapping is read-only and owned exclusively by this handle.
    unsafe impl Send for MapHandle {}
    unsafe impl Sync for MapHandle {}

    impl MapHandle {
        pub(super) fn map(f: &File, len: usize) -> io::Result<MapHandle> {
            let ptr = unsafe {
                mmap(
                    std::ptr::null_mut(),
                    len,
                    PROT_READ,
                    MAP_PRIVATE,
                    f.as_raw_fd(),
                    0,
                )
            };
            // MAP_FAILED is (void*)-1 on every unix we target.
            if ptr as isize == -1 || ptr.is_null() {
                return Err(io::Error::last_os_error());
            }
            Ok(MapHandle { ptr, len })
        }

        pub(super) fn as_slice(&self) -> &[u8] {
            unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
        }
    }

    impl Drop for MapHandle {
        fn drop(&mut self) {
            unsafe {
                munmap(self.ptr, self.len);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;

    #[test]
    fn open_reads_whole_file() {
        let tmp = TempDir::new("mapped");
        let path = tmp.join("blob.bin");
        let bytes: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        std::fs::write(&path, &bytes).unwrap();
        let m = Mapped::open(&path).unwrap();
        assert_eq!(&m[..], &bytes[..]);
        assert_eq!(m.len(), 10_000);
    }

    #[test]
    fn open_empty_file_is_empty_slice() {
        let tmp = TempDir::new("mapped");
        let path = tmp.join("empty.bin");
        std::fs::write(&path, b"").unwrap();
        let m = Mapped::open(&path).unwrap();
        assert!(m.is_empty());
    }

    #[test]
    fn missing_file_is_io_error() {
        let tmp = TempDir::new("mapped");
        assert!(Mapped::open(&tmp.join("nope.bin")).is_err());
    }
}
