//! Background compaction: a per-collection worker thread that watches
//! delta growth and tombstone debt and folds them away with
//! [`MutableCollection::compact`].
//!
//! The worker only ever *calls* `compact()` — all correctness lives in
//! the collection (mutation mutex, read-mostly state, O(1) generation
//! swap), so a compaction pass never blocks searches and never races
//! mutations. Errors are counted and logged, not fatal: a failed pass
//! leaves the previous generation serving and the next poll retries.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::collection::MutableCollection;

/// When the worker decides a pass is worth it.
#[derive(Clone, Copy, Debug)]
pub struct CompactorConfig {
    /// Compact once this many live delta rows accumulate.
    pub delta_threshold: usize,
    /// … or this many sealed rows are tombstone-masked.
    pub tombstone_threshold: usize,
    /// How often the worker re-checks the pressure signals.
    pub poll_interval: Duration,
}

impl Default for CompactorConfig {
    fn default() -> Self {
        CompactorConfig {
            delta_threshold: 4096,
            tombstone_threshold: 1024,
            poll_interval: Duration::from_millis(200),
        }
    }
}

/// Handle to one collection's background compaction thread.
pub struct Compactor {
    stop: Arc<AtomicBool>,
    passes: Arc<AtomicU64>,
    errors: Arc<AtomicU64>,
    handle: Option<JoinHandle<()>>,
}

impl Compactor {
    /// Spawn the worker. It polls until [`Compactor::stop`] (or drop).
    pub fn spawn(coll: Arc<MutableCollection>, cfg: CompactorConfig) -> std::io::Result<Compactor> {
        let stop = Arc::new(AtomicBool::new(false));
        let passes = Arc::new(AtomicU64::new(0));
        let errors = Arc::new(AtomicU64::new(0));
        let (stop2, passes2, errors2) = (stop.clone(), passes.clone(), errors.clone());
        let handle = std::thread::Builder::new()
            .name("amips-compactor".into())
            .spawn(move || {
                while !stop2.load(Ordering::Acquire) {
                    std::thread::sleep(cfg.poll_interval);
                    if stop2.load(Ordering::Acquire) {
                        break;
                    }
                    let due = coll.delta_live() >= cfg.delta_threshold.max(1)
                        || coll.tombstone_count() >= cfg.tombstone_threshold.max(1);
                    if !due {
                        continue;
                    }
                    match coll.compact() {
                        Ok(_) => {
                            passes2.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => {
                            errors2.fetch_add(1, Ordering::Relaxed);
                            eprintln!("amips compactor: pass failed: {e:#}");
                        }
                    }
                }
            })?;
        Ok(Compactor {
            stop,
            passes,
            errors,
            handle: Some(handle),
        })
    }

    /// Completed compaction passes.
    pub fn passes(&self) -> u64 {
        self.passes.load(Ordering::Relaxed)
    }

    /// Failed compaction passes (previous generation kept serving).
    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    /// Shared handles to the (passes, errors) counters, for exporters
    /// that outlive-or-predate this handle (e.g. the metrics listener).
    pub fn counter_handles(&self) -> (Arc<AtomicU64>, Arc<AtomicU64>) {
        (self.passes.clone(), self.errors.clone())
    }

    /// Signal the worker and join it.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Compactor {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::spec::IndexSpec;
    use crate::tensor::Tensor;
    use crate::util::{Rng, TempDir};

    #[test]
    fn compacts_when_delta_grows_past_threshold() {
        let tmp = TempDir::new("compactor");
        let spec = IndexSpec::default_for("flat").unwrap();
        let coll = Arc::new(MutableCollection::create(&tmp.join("c.seg"), spec, 8, 1).unwrap());
        let mut keys = Tensor::zeros(&[64, 8]);
        Rng::new(2).fill_normal(keys.data_mut(), 1.0);
        coll.insert(&keys).unwrap();
        let cfg = CompactorConfig {
            delta_threshold: 32,
            tombstone_threshold: 1024,
            poll_interval: Duration::from_millis(5),
        };
        let worker = Compactor::spawn(coll.clone(), cfg).unwrap();
        // the worker should fold the 64-row delta within a few polls
        for _ in 0..400 {
            if coll.delta_live() == 0 && coll.generation() > 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        worker.stop();
        assert_eq!(coll.delta_live(), 0, "delta never compacted");
        assert!(coll.generation() >= 1);
        assert_eq!(coll.len(), 64);
    }

    #[test]
    fn idle_worker_stops_cleanly() {
        let tmp = TempDir::new("compactor");
        let spec = IndexSpec::default_for("flat").unwrap();
        let coll = Arc::new(MutableCollection::create(&tmp.join("c.seg"), spec, 4, 1).unwrap());
        let worker = Compactor::spawn(coll.clone(), CompactorConfig::default()).unwrap();
        drop(worker); // drop path joins too
        assert_eq!(coll.generation(), 0);
    }
}
