//! Generation manifests: the commit records of a mutable collection.
//!
//! One committed generation is one `gen-<n>.tsv` file in the
//! collection directory — a human-auditable TSV listing the sealed
//! segments and tombstones that make up that generation, finished by
//! an FNV-1a checksum over every preceding byte (the same hash the
//! AMIX artifact container uses). Manifests are written to a `.tmp`
//! sibling and renamed into place, so a crash leaves either the old
//! committed generation or the new one — never a half-written record
//! under the committed name.
//!
//! ```text
//! # amips generation manifest v1
//! gen     3
//! dim     32
//! seed    7
//! next_id 4096
//! segment seg-000003-0.ams
//! tombstone       seg-000003-0.ams        17
//! checksum        9f3c2a1b00e4d577
//! ```

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::index::artifact::fnv1a64;

/// Parsed contents of one `gen-<n>.tsv` commit record.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GenManifest {
    /// Generation number; also encoded in the file name.
    pub gen: u64,
    /// Key dimensionality of every segment in this generation.
    pub dim: usize,
    /// Build seed compactions fold into [`crate::index::BuildCtx`].
    pub seed: u64,
    /// Next unassigned global id — ids are never reused.
    pub next_id: u32,
    /// Sealed segment file names, in search fan-out order.
    pub segments: Vec<String>,
    /// `(segment file, local row)` pairs masked at search time.
    pub tombstones: Vec<(String, u32)>,
}

impl GenManifest {
    /// Canonical file name of a generation's manifest.
    pub fn file_name(gen: u64) -> String {
        format!("gen-{gen:06}.tsv")
    }

    /// Render to the checksummed TSV text.
    pub fn render(&self) -> String {
        let mut out = String::from("# amips generation manifest v1\n");
        out.push_str(&format!("gen\t{}\n", self.gen));
        out.push_str(&format!("dim\t{}\n", self.dim));
        out.push_str(&format!("seed\t{}\n", self.seed));
        out.push_str(&format!("next_id\t{}\n", self.next_id));
        for seg in &self.segments {
            out.push_str(&format!("segment\t{seg}\n"));
        }
        for (seg, lid) in &self.tombstones {
            out.push_str(&format!("tombstone\t{seg}\t{lid}\n"));
        }
        out.push_str(&format!("checksum\t{:016x}\n", fnv1a64(out.as_bytes())));
        out
    }

    /// Strict parse + checksum verification of [`render`]ed text.
    /// Anything off — missing keys, unknown keys, malformed counts,
    /// trailing bytes, a checksum mismatch — is a typed error, so a
    /// torn or bit-flipped manifest can never be half-trusted.
    pub fn parse(text: &str) -> Result<GenManifest> {
        if !text.ends_with('\n') {
            bail!("generation manifest not newline-terminated (torn write?)");
        }
        let pos = match text.rfind("\nchecksum\t") {
            Some(p) => p + 1,
            None => bail!("generation manifest missing checksum line"),
        };
        let prefix = &text[..pos];
        let mut tail = text[pos..].lines();
        let sum_line = tail.next().unwrap_or_default();
        if tail.any(|l| !l.trim().is_empty()) {
            bail!("generation manifest has content after the checksum line");
        }
        let want = sum_line
            .strip_prefix("checksum\t")
            .and_then(|h| u64::from_str_radix(h.trim(), 16).ok())
            .context("generation manifest checksum line malformed")?;
        let got = fnv1a64(prefix.as_bytes());
        if got != want {
            bail!("generation manifest checksum mismatch: computed {got:016x}, recorded {want:016x}");
        }

        let (mut gen, mut dim, mut seed, mut next_id) = (None, None, None, None);
        let mut segments = Vec::new();
        let mut tombstones = Vec::new();
        for (ln, line) in prefix.lines().enumerate() {
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split('\t');
            let key = parts.next().unwrap_or_default();
            let val = parts.next();
            match key {
                "gen" | "dim" | "seed" | "next_id" => {
                    let v: u64 = val
                        .and_then(|v| v.parse().ok())
                        .with_context(|| format!("manifest line {}: bad {key} value", ln + 1))?;
                    if parts.next().is_some() {
                        bail!("manifest line {}: trailing fields after {key}", ln + 1);
                    }
                    let slot = match key {
                        "gen" => &mut gen,
                        "dim" => &mut dim,
                        "seed" => &mut seed,
                        _ => &mut next_id,
                    };
                    if slot.replace(v).is_some() {
                        bail!("manifest line {}: duplicate {key}", ln + 1);
                    }
                }
                "segment" => {
                    let file = val.context("manifest segment line missing file")?;
                    check_segment_name(file)?;
                    if parts.next().is_some() {
                        bail!("manifest line {}: trailing fields after segment", ln + 1);
                    }
                    segments.push(file.to_string());
                }
                "tombstone" => {
                    let file = val.context("manifest tombstone line missing file")?;
                    check_segment_name(file)?;
                    let lid: u32 = parts
                        .next()
                        .and_then(|v| v.parse().ok())
                        .with_context(|| format!("manifest line {}: bad tombstone row", ln + 1))?;
                    if parts.next().is_some() {
                        bail!("manifest line {}: trailing fields after tombstone", ln + 1);
                    }
                    tombstones.push((file.to_string(), lid));
                }
                other => bail!("manifest line {}: unknown key {other:?}", ln + 1),
            }
        }
        let m = GenManifest {
            gen: gen.context("generation manifest missing gen")?,
            dim: dim.context("generation manifest missing dim")? as usize,
            seed: seed.context("generation manifest missing seed")?,
            next_id: u32::try_from(next_id.context("generation manifest missing next_id")?)
                .context("generation manifest next_id exceeds u32")?,
            segments,
            tombstones,
        };
        for (file, _) in &m.tombstones {
            if !m.segments.contains(file) {
                bail!("generation manifest tombstone references unlisted segment {file:?}");
            }
        }
        Ok(m)
    }

    /// Read + parse + cross-check that the file name encodes the same
    /// generation the record claims (catches stray copies/renames).
    pub fn read(path: &Path) -> Result<GenManifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading generation manifest {}", path.display()))?;
        let m = Self::parse(&text)
            .with_context(|| format!("parsing generation manifest {}", path.display()))?;
        let named = path
            .file_name()
            .and_then(|n| n.to_str())
            .and_then(parse_gen_file_name);
        if named != Some(m.gen) {
            bail!(
                "generation manifest {} records gen {} but is named for {:?}",
                path.display(),
                m.gen,
                named
            );
        }
        Ok(m)
    }

    /// Commit this manifest: write `gen-<n>.tsv.tmp`, fsync-free
    /// rename into place (same discipline as the catalog manifest).
    pub fn write(&self, dir: &Path) -> Result<PathBuf> {
        let path = dir.join(Self::file_name(self.gen));
        let tmp = dir.join(format!("{}.tmp", Self::file_name(self.gen)));
        std::fs::write(&tmp, self.render())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("committing {}", path.display()))?;
        Ok(path)
    }
}

/// Segment file names live flat inside the collection directory; a
/// manifest can never point the loader anywhere else.
fn check_segment_name(name: &str) -> Result<()> {
    let ok = name.starts_with("seg-")
        && name.ends_with(".ams")
        && name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '.' || c == '_');
    if !ok {
        bail!("manifest references invalid segment file name {name:?}");
    }
    Ok(())
}

/// `gen-000123.tsv` → `Some(123)`.
pub(crate) fn parse_gen_file_name(name: &str) -> Option<u64> {
    let digits = name.strip_prefix("gen-")?.strip_suffix(".tsv")?;
    if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// Enumerate committed generations in `dir`, newest first. Files that
/// merely look similar (`.tmp` leftovers, foreign names) are ignored.
pub fn list_generations(dir: &Path) -> Result<Vec<(u64, PathBuf)>> {
    let mut found = Vec::new();
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("listing collection directory {}", dir.display()))?;
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some(gen) = parse_gen_file_name(name) {
            found.push((gen, entry.path()));
        }
    }
    found.sort_by(|a, b| b.0.cmp(&a.0));
    Ok(found)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;

    fn sample() -> GenManifest {
        GenManifest {
            gen: 3,
            dim: 32,
            seed: 7,
            next_id: 4096,
            segments: vec!["seg-000003-0.ams".into(), "seg-000002-1.ams".into()],
            tombstones: vec![("seg-000002-1.ams".into(), 17), ("seg-000002-1.ams".into(), 2)],
        }
    }

    #[test]
    fn render_parse_round_trip() {
        let m = sample();
        assert_eq!(GenManifest::parse(&m.render()).unwrap(), m);
    }

    #[test]
    fn every_byte_flip_is_detected() {
        let text = sample().render();
        let bytes = text.as_bytes();
        for i in 0..bytes.len() {
            let mut mutated = bytes.to_vec();
            mutated[i] ^= 0x11;
            let Ok(s) = String::from_utf8(mutated) else { continue };
            assert!(
                GenManifest::parse(&s).is_err(),
                "flip at byte {i} went undetected"
            );
        }
    }

    #[test]
    fn truncations_are_detected() {
        let text = sample().render();
        for cut in 0..text.len() {
            if !text.is_char_boundary(cut) {
                continue;
            }
            assert!(GenManifest::parse(&text[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn rejects_structural_abuse() {
        // content after the checksum line
        let mut text = sample().render();
        text.push_str("segment\tseg-evil-0.ams\n");
        assert!(GenManifest::parse(&text).is_err());
        // tombstone pointing at an unlisted segment
        let mut m = sample();
        m.tombstones.push(("seg-999999-9.ams".into(), 0));
        assert!(GenManifest::parse(&m.render()).is_err());
        // path traversal in a segment name never parses
        let mut m = sample();
        m.segments.push("../../etc/passwd".into());
        assert!(GenManifest::parse(&m.render()).is_err());
    }

    #[test]
    fn write_then_read_and_name_cross_check() {
        let tmp = TempDir::new("genman");
        let m = sample();
        let path = m.write(tmp.path()).unwrap();
        assert_eq!(GenManifest::read(&path).unwrap(), m);
        assert!(!tmp.join("gen-000003.tsv.tmp").exists());
        // a renamed copy is refused even though its checksum is fine
        let copy = tmp.join("gen-000009.tsv");
        std::fs::copy(&path, &copy).unwrap();
        assert!(GenManifest::read(&copy).is_err());
    }

    #[test]
    fn list_generations_newest_first() {
        let tmp = TempDir::new("genlist");
        for gen in [1u64, 4, 2] {
            let mut m = sample();
            m.gen = gen;
            m.write(tmp.path()).unwrap();
        }
        std::fs::write(tmp.join("gen-000009.tsv.tmp"), b"torn").unwrap();
        std::fs::write(tmp.join("notes.txt"), b"x").unwrap();
        let gens: Vec<u64> = list_generations(tmp.path())
            .unwrap()
            .into_iter()
            .map(|(g, _)| g)
            .collect();
        assert_eq!(gens, vec![4, 2, 1]);
    }
}
