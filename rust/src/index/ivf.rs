//! IVF-Flat: the FAISS-IVF analog used in the headline integration
//! experiment (Sec. 4.4, Fig. 5).
//!
//! Build: spherical k-means over the keys -> `nlist` Voronoi cells with
//! contiguous per-cell key storage (cache-friendly scans). Query: score
//! the query against all centroids, take the `Effort`-resolved number of
//! best cells, scan their members exhaustively. Swapping the query vector
//! for KeyNet's ŷ(x) — and nothing else — is the paper's drop-in
//! integration; swapping centroid ranking for a learned router is
//! [`crate::api::RoutedSearcher`] over [`IvfIndex::search_cells`].

use std::io::Read;

use anyhow::{ensure, Result};

use crate::api::Effort;
use crate::index::artifact;
use crate::index::kmeans::KMeans;
use crate::index::spec::{IndexSpec, IvfSpec};
use crate::index::traits::{SearchCost, SearchResult, TopK, VectorIndex};
use crate::tensor::{dot, gemm_nt_tile, Tensor};

/// Batch × centroids coarse ranking shared by the IVF-family backbones
/// (IVF, ScaNN, SOAR): one [`gemm_nt_tile`] over the centroid matrix,
/// then one [`TopK`] per query row. Scores go through the same `dot` as
/// scoring each centroid alone, so every query's cell list is identical
/// to its per-query ranking.
pub(crate) fn rank_cells_tensor(
    queries: &Tensor,
    centroids: &Tensor,
    nprobe: usize,
) -> Vec<Vec<u32>> {
    let (b, nlist, d) = (queries.rows(), centroids.rows(), centroids.row_width());
    let keep = nprobe.max(1).min(nlist);
    let mut cscores = vec![0.0f32; b * nlist];
    gemm_nt_tile(queries.data(), centroids.data(), d, &mut cscores);
    cscores
        .chunks(nlist)
        .map(|row| {
            let mut top = TopK::new(keep);
            for (j, &s) in row.iter().enumerate() {
                top.offer(s, j as u32);
            }
            top.into_sorted().0
        })
        .collect()
}

/// Invert per-query cell lists into per-cell prober lists (which
/// queries probe each cell), preserving multiplicity.
pub(crate) fn invert_to_probers<C: AsRef<[u32]>>(cells: &[C], nlist: usize) -> Vec<Vec<u32>> {
    let mut probers: Vec<Vec<u32>> = vec![Vec::new(); nlist];
    for (q, list) in cells.iter().enumerate() {
        for &cell in list.as_ref() {
            probers[cell as usize].push(q as u32);
        }
    }
    probers
}

pub struct IvfIndex {
    pub nlist: usize,
    d: usize,
    centroids: Tensor, // [nlist, d]
    /// Keys regrouped contiguously by cell.
    packed: Tensor, // [n, d]
    /// Original key id for each packed row.
    ids: Vec<u32>,
    /// Cell start offsets into `packed`/`ids` (len = nlist + 1).
    offsets: Vec<usize>,
    /// Lloyd iterations used at build time (spec echo only; indexes
    /// built via [`IvfIndex::from_clustering`] report the default).
    iters: usize,
}

impl IvfIndex {
    /// Build from raw keys. `nlist` cells, `iters` Lloyd iterations.
    pub fn build(keys: &Tensor, nlist: usize, iters: usize, seed: u64) -> IvfIndex {
        let km = KMeans::fit(keys, nlist, iters, seed);
        let mut idx = Self::from_clustering(keys, km.centroids, &km.assign);
        idx.iters = iters;
        idx
    }

    /// Build from an existing clustering (shared with routing experiments).
    pub fn from_clustering(keys: &Tensor, centroids: Tensor, assign: &[u32]) -> IvfIndex {
        let n = keys.rows();
        let d = keys.row_width();
        let nlist = centroids.rows();
        assert_eq!(assign.len(), n);
        let mut counts = vec![0usize; nlist];
        for &a in assign {
            counts[a as usize] += 1;
        }
        let mut offsets = vec![0usize; nlist + 1];
        for j in 0..nlist {
            offsets[j + 1] = offsets[j] + counts[j];
        }
        let mut cursor = offsets.clone();
        let mut packed = Tensor::zeros(&[n, d]);
        let mut ids = vec![0u32; n];
        for i in 0..n {
            let cell = assign[i] as usize;
            let pos = cursor[cell];
            cursor[cell] += 1;
            packed.row_mut(pos).copy_from_slice(keys.row(i));
            ids[pos] = i as u32;
        }
        IvfIndex {
            nlist,
            d,
            centroids,
            packed,
            ids,
            offsets,
            iters: IvfSpec::default().iters,
        }
    }

    /// Deserialize from an artifact payload (see [`crate::index::artifact`]).
    pub(crate) fn read_payload(r: &mut dyn Read) -> Result<IvfIndex> {
        let centroids = artifact::r_tensor(r)?;
        let packed = artifact::r_tensor(r)?;
        let ids = artifact::r_u32s(r)?;
        let offsets = artifact::r_usizes(r)?;
        let iters = artifact::r_u64(r)? as usize;
        let nlist = centroids.rows();
        let d = packed.row_width();
        ensure!(
            nlist >= 1
                && centroids.row_width() == d
                && packed.rows() == ids.len()
                && offsets.len() == nlist + 1
                && offsets.last().copied() == Some(ids.len())
                && offsets.windows(2).all(|w| w[0] <= w[1])
                // ids must stay in-range: LeanVec re-ranks by indexing
                // its full-dim keys with them, so an out-of-range id in
                // a checksum-valid artifact must fail here, not panic
                // on the first query
                && ids.iter().all(|&id| (id as usize) < ids.len()),
            "inconsistent IVF payload: {} cells, {} packed rows, {} ids, {} offsets",
            nlist,
            packed.rows(),
            ids.len(),
            offsets.len()
        );
        Ok(IvfIndex {
            nlist,
            d,
            centroids,
            packed,
            ids,
            offsets,
            iters,
        })
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn centroids(&self) -> &Tensor {
        &self.centroids
    }

    pub fn cell_len(&self, j: usize) -> usize {
        self.offsets[j + 1] - self.offsets[j]
    }

    /// Rank cells by centroid score (descending), returning the top
    /// `nprobe` cell ids. Cost: nlist * d multiply-adds.
    pub fn rank_cells(&self, query: &[f32], nprobe: usize) -> Vec<u32> {
        let mut top = TopK::new(nprobe.max(1).min(self.nlist));
        for j in 0..self.nlist {
            top.offer(dot(query, self.centroids.row(j)), j as u32);
        }
        top.into_sorted().0
    }

    /// [`IvfIndex::rank_cells`] for a whole batch (see
    /// [`rank_cells_tensor`]).
    fn rank_cells_batch(&self, queries: &Tensor, nprobe: usize) -> Vec<Vec<u32>> {
        rank_cells_tensor(queries, &self.centroids, nprobe)
    }

    /// Exact top-k over an explicit list of cells (the routed-search
    /// entry point: the caller — centroid ranking or a learned router —
    /// owns cell selection and its cost; this accounts only the scan).
    pub fn search_cells(&self, query: &[f32], cells: &[u32], k: usize) -> SearchResult {
        let mut top = TopK::new(k);
        let scanned = self.scan_cells(query, cells, &mut top);
        let (ids, scores) = top.into_sorted();
        SearchResult {
            ids,
            scores,
            cost: SearchCost {
                flops: scanned * self.d as u64 * 2,
                keys_scanned: scanned,
                cells_probed: cells.len() as u64,
            },
        }
    }

    /// Scan an explicit list of cells, maintaining a shared TopK.
    fn scan_cells(&self, query: &[f32], cells: &[u32], top: &mut TopK) -> u64 {
        let mut scanned = 0u64;
        for &cell in cells {
            let (s, e) = (self.offsets[cell as usize], self.offsets[cell as usize + 1]);
            for pos in s..e {
                top.offer(dot(query, self.packed.row(pos)), self.ids[pos]);
            }
            scanned += (e - s) as u64;
        }
        scanned
    }

    /// Grouped multi-query cell scan: invert the per-query cell lists
    /// into per-cell prober lists, then stream each probed cell's keys
    /// *once*, scoring every query that probes it while the key row is
    /// hot. Per-query results and scan counts are identical to calling
    /// [`IvfIndex::scan_cells`] per query — [`TopK`] output does not
    /// depend on push order, and duplicate cells in a query's list
    /// score (and count) with the same multiplicity either way.
    fn scan_cells_grouped(&self, queries: &Tensor, cells: &[&[u32]], k: usize) -> Vec<(TopK, u64)> {
        let b = queries.rows();
        debug_assert_eq!(cells.len(), b);
        let probers = invert_to_probers(cells, self.nlist);
        let mut tops: Vec<TopK> = (0..b).map(|_| TopK::new(k)).collect();
        let mut scanned = vec![0u64; b];
        for (cell, qs) in probers.iter().enumerate() {
            if qs.is_empty() {
                continue;
            }
            let (s, e) = (self.offsets[cell], self.offsets[cell + 1]);
            for pos in s..e {
                let key = self.packed.row(pos);
                let id = self.ids[pos];
                for &q in qs {
                    tops[q as usize].offer(dot(queries.row(q as usize), key), id);
                }
            }
            for &q in qs {
                scanned[q as usize] += (e - s) as u64;
            }
        }
        tops.into_iter().zip(scanned).collect()
    }

    /// Fused multi-query [`IvfIndex::search_cells`]: one cell list per
    /// query (the batched routed-search entry point — the caller owns
    /// cell selection and its cost). Results are bit-identical to
    /// calling `search_cells` per query.
    pub fn search_cells_batch(
        &self,
        queries: &Tensor,
        cells: &[&[u32]],
        k: usize,
    ) -> Vec<SearchResult> {
        assert_eq!(queries.rows(), cells.len());
        self.scan_cells_grouped(queries, cells, k)
            .into_iter()
            .zip(cells)
            .map(|((top, scanned), list)| {
                let (ids, scores) = top.into_sorted();
                SearchResult {
                    ids,
                    scores,
                    cost: SearchCost {
                        flops: scanned * self.d as u64 * 2,
                        keys_scanned: scanned,
                        cells_probed: list.len() as u64,
                    },
                }
            })
            .collect()
    }

    /// Centroid-ranked probe search (the classic IVF query path).
    fn search_probes(&self, query: &[f32], k: usize, nprobe: usize) -> SearchResult {
        let nprobe = nprobe.clamp(1, self.nlist);
        let cells = self.rank_cells(query, nprobe);
        let mut top = TopK::new(k);
        let scanned = self.scan_cells(query, &cells, &mut top);
        let (ids, scores) = top.into_sorted();
        SearchResult {
            ids,
            scores,
            cost: SearchCost {
                flops: (self.nlist as u64 + scanned) * self.d as u64 * 2,
                keys_scanned: scanned,
                cells_probed: nprobe as u64,
            },
        }
    }
}

impl VectorIndex for IvfIndex {
    fn name(&self) -> &str {
        "ivf"
    }

    fn len(&self) -> usize {
        self.ids.len()
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn n_cells(&self) -> usize {
        self.nlist
    }

    fn search_effort(&self, query: &[f32], k: usize, effort: Effort) -> SearchResult {
        self.search_probes(query, k, effort.resolve(self.nlist))
    }

    /// Fused batched probe: batch × centroids as one gemm tile, then
    /// the grouped cell scan ([`IvfIndex::scan_cells_grouped`]) so each
    /// probed cell's keys stream once for every query probing it.
    /// Bit-identical to per-query [`IvfIndex::search_effort`].
    fn search_batch_effort(&self, queries: &Tensor, k: usize, effort: Effort) -> Vec<SearchResult> {
        let b = queries.rows();
        if b == 0 {
            return Vec::new();
        }
        let nprobe = effort.resolve(self.nlist);
        let cells = self.rank_cells_batch(queries, nprobe);
        let cell_refs: Vec<&[u32]> = cells.iter().map(|c| c.as_slice()).collect();
        self.scan_cells_grouped(queries, &cell_refs, k)
            .into_iter()
            .map(|(top, scanned)| {
                let (ids, scores) = top.into_sorted();
                SearchResult {
                    ids,
                    scores,
                    cost: SearchCost {
                        flops: (self.nlist as u64 + scanned) * self.d as u64 * 2,
                        keys_scanned: scanned,
                        cells_probed: nprobe as u64,
                    },
                }
            })
            .collect()
    }

    fn spec(&self) -> IndexSpec {
        IndexSpec::Ivf(IvfSpec {
            nlist: self.nlist,
            iters: self.iters,
        })
    }

    fn write_payload(&self, w: &mut Vec<u8>) -> Result<()> {
        artifact::w_tensor(w, &self.centroids)?;
        artifact::w_tensor(w, &self.packed)?;
        artifact::w_u32s(w, &self.ids)?;
        artifact::w_usizes(w, &self.offsets)?;
        artifact::w_u64(w, self.iters as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::flat::FlatIndex;
    use crate::tensor::normalize_rows;
    use crate::util::Rng;

    fn unit_keys(n: usize, d: usize, seed: u64) -> Tensor {
        let mut t = Tensor::zeros(&[n, d]);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        normalize_rows(&mut t);
        t
    }

    #[test]
    fn full_probe_matches_flat() {
        let keys = unit_keys(400, 16, 1);
        let ivf = IvfIndex::build(&keys, 8, 10, 2);
        let flat = FlatIndex::new(keys.clone());
        let q = unit_keys(10, 16, 3);
        for i in 0..10 {
            let a = ivf.search_effort(q.row(i), 5, Effort::Exhaustive);
            let b = flat.search_effort(q.row(i), 5, Effort::Exhaustive);
            assert_eq!(a.ids, b.ids, "query {i}");
        }
    }

    #[test]
    fn packed_rows_preserve_keys() {
        let keys = unit_keys(100, 8, 4);
        let ivf = IvfIndex::build(&keys, 4, 8, 5);
        // every original key must appear exactly once in packed storage
        let mut seen = vec![false; 100];
        for pos in 0..100 {
            let id = ivf.ids[pos] as usize;
            assert!(!seen[id]);
            seen[id] = true;
            assert_eq!(ivf.packed.row(pos), keys.row(id));
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn recall_improves_with_nprobe() {
        let keys = unit_keys(600, 16, 6);
        let ivf = IvfIndex::build(&keys, 16, 10, 7);
        let flat = FlatIndex::new(keys.clone());
        let q = unit_keys(50, 16, 8);
        let mut hits = vec![0usize; 3];
        for i in 0..50 {
            let truth = flat.search_effort(q.row(i), 1, Effort::Exhaustive).ids[0];
            for (pi, np) in [1usize, 4, 16].iter().enumerate() {
                let res = ivf.search_effort(q.row(i), 1, Effort::Probes(*np));
                if res.ids.first() == Some(&truth) {
                    hits[pi] += 1;
                }
            }
        }
        assert!(hits[0] <= hits[1] && hits[1] <= hits[2], "{hits:?}");
        assert_eq!(hits[2], 50); // full probe is exact
    }

    #[test]
    fn cost_accounting_scales_with_nprobe() {
        let keys = unit_keys(300, 8, 9);
        let ivf = IvfIndex::build(&keys, 10, 8, 10);
        let q = unit_keys(1, 8, 11);
        let c1 = ivf.search_effort(q.row(0), 1, Effort::Probes(1)).cost;
        let c5 = ivf.search_effort(q.row(0), 1, Effort::Probes(5)).cost;
        assert!(c5.keys_scanned > c1.keys_scanned);
        assert_eq!(c1.cells_probed, 1);
        assert_eq!(c5.cells_probed, 5);
        assert!(c5.flops > c1.flops);
    }

    #[test]
    fn search_cells_matches_probe_path() {
        // explicit-cell search with the centroid ranking must equal the
        // classic probe path (modulo the selection cost, excluded here)
        let keys = unit_keys(250, 8, 12);
        let ivf = IvfIndex::build(&keys, 6, 8, 13);
        let q = unit_keys(1, 8, 14);
        let cells = ivf.rank_cells(q.row(0), 3);
        let a = ivf.search_cells(q.row(0), &cells, 4);
        let b = ivf.search_effort(q.row(0), 4, Effort::Probes(3));
        assert_eq!(a.ids, b.ids);
        assert_eq!(a.scores, b.scores);
        assert_eq!(a.cost.keys_scanned, b.cost.keys_scanned);
        // selection flops only on the probe path
        assert!(a.cost.flops < b.cost.flops);
    }

    #[test]
    fn batched_search_is_bit_identical_to_per_query() {
        let keys = unit_keys(350, 12, 17);
        let ivf = IvfIndex::build(&keys, 7, 10, 18);
        let q = unit_keys(9, 12, 19);
        for effort in [Effort::Probes(1), Effort::Probes(3), Effort::Auto, Effort::Exhaustive] {
            let batched = ivf.search_batch_effort(&q, 4, effort);
            for i in 0..9 {
                let single = ivf.search_effort(q.row(i), 4, effort);
                assert_eq!(batched[i].ids, single.ids, "{effort:?} query {i}");
                assert_eq!(batched[i].scores, single.scores, "{effort:?} query {i}");
                assert_eq!(batched[i].cost, single.cost, "{effort:?} query {i}");
            }
        }
    }

    #[test]
    fn search_cells_batch_matches_per_query_search_cells() {
        let keys = unit_keys(280, 8, 20);
        let ivf = IvfIndex::build(&keys, 6, 8, 21);
        let q = unit_keys(5, 8, 22);
        // heterogeneous per-query cell lists, including an empty one
        let lists: Vec<Vec<u32>> = vec![
            ivf.rank_cells(q.row(0), 2),
            ivf.rank_cells(q.row(1), 6),
            vec![],
            vec![3],
            ivf.rank_cells(q.row(4), 4),
        ];
        let refs: Vec<&[u32]> = lists.iter().map(|l| l.as_slice()).collect();
        let batched = ivf.search_cells_batch(&q, &refs, 3);
        for i in 0..5 {
            let single = ivf.search_cells(q.row(i), &lists[i], 3);
            assert_eq!(batched[i].ids, single.ids, "query {i}");
            assert_eq!(batched[i].scores, single.scores, "query {i}");
            assert_eq!(batched[i].cost, single.cost, "query {i}");
        }
    }

    #[test]
    fn frac_and_auto_effort_resolve_against_nlist() {
        let keys = unit_keys(200, 8, 15);
        let ivf = IvfIndex::build(&keys, 16, 8, 16);
        let half = ivf.search_effort(keys.row(0), 1, Effort::Frac(0.5));
        assert_eq!(half.cost.cells_probed, 8);
        let auto = ivf.search_effort(keys.row(0), 1, Effort::Auto);
        assert_eq!(auto.cost.cells_probed, 4);
    }
}
