//! Index substrates: every approximate-search backbone the paper
//! evaluates KeyNet against (Sec. 4.4, App. A.8), built from scratch.
//! All of them serve the typed [`crate::api::Searcher`] surface through
//! the [`VectorIndex`] trait.
//!
//! * [`flat`] — exhaustive MIPS (ground truth + within-cluster scans)
//! * [`kmeans`] — spherical k-means (coarse quantizer + dataset partitioner)
//! * [`ivf`] — FAISS-IVF-Flat analog: coarse cells + probed scan
//! * [`pq`] — product quantization codec + the flat `IndexPQ` analog
//! * [`sq`] — SQ8 scalar-quantized flat scan + exact re-rank
//! * [`scann`] — ScaNN analog: IVF + *anisotropic* PQ scoring
//! * [`soar`] — SOAR analog: IVF with redundant spilled assignments
//! * [`leanvec`] — LeanVec analog: learned linear projection + IVF,
//!   full-dim rescoring

pub mod flat;
pub mod ivf;
pub mod kmeans;
pub mod leanvec;
pub mod pq;
pub mod scann;
pub mod soar;
pub mod sq;
pub mod traits;

pub use traits::{SearchCost, SearchResult, VectorIndex};

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// The seven index backbones served by the unified API.
pub const BACKBONES: [&str; 7] = ["flat", "ivf", "pq", "sq8", "scann", "soar", "leanvec"];

/// Largest PQ subspace count `<= 8` that divides `d`.
fn pq_m(d: usize) -> usize {
    for m in [8usize, 4, 2] {
        if d % m == 0 {
            return m;
        }
    }
    1
}

/// Build any backbone by name with shared defaults — the one construction
/// path the CLI, benches and conformance tests agree on.
/// `sample_queries` makes LeanVec's projection query-aware when given.
pub fn build_backend(
    name: &str,
    keys: &Tensor,
    sample_queries: Option<&Tensor>,
    nlist: usize,
    seed: u64,
) -> Result<Box<dyn VectorIndex>> {
    let d = keys.row_width();
    Ok(match name {
        "flat" => Box::new(flat::FlatIndex::new(keys.clone())),
        "ivf" => Box::new(ivf::IvfIndex::build(keys, nlist, 15, seed)),
        "pq" => Box::new(pq::PqIndex::build(keys, pq_m(d), 10, 1.0, seed)),
        "sq8" => Box::new(sq::SqIndex::build(keys)),
        "scann" => Box::new(scann::ScannIndex::build(keys, nlist, pq_m(d), 4.0, seed)),
        "soar" => Box::new(soar::SoarIndex::build(keys, nlist, 6, seed)),
        "leanvec" => Box::new(leanvec::LeanVecIndex::build(
            keys,
            (d / 2).clamp(1, d).max(4.min(d)),
            nlist,
            sample_queries,
            seed,
        )),
        other => bail!("unknown backend '{other}'; expected one of {BACKBONES:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::normalize_rows;
    use crate::util::Rng;

    #[test]
    fn builds_every_backbone() {
        let mut keys = Tensor::zeros(&[200, 16]);
        Rng::new(1).fill_normal(keys.data_mut(), 1.0);
        normalize_rows(&mut keys);
        for name in BACKBONES {
            let idx = build_backend(name, &keys, None, 4, 7).unwrap();
            assert_eq!(idx.len(), 200, "{name}");
            assert_eq!(idx.dim(), 16, "{name}");
            assert!(idx.n_cells() >= 1, "{name}");
        }
        assert!(build_backend("hnsw", &keys, None, 4, 7).is_err());
    }

    #[test]
    fn pq_m_divides() {
        assert_eq!(pq_m(16), 8);
        assert_eq!(pq_m(12), 4);
        assert_eq!(pq_m(6), 2);
        assert_eq!(pq_m(7), 1);
    }
}
