//! Index substrates: every approximate-search backbone the paper
//! evaluates KeyNet against (Sec. 4.4, App. A.8), built from scratch.
//!
//! * [`flat`] — exhaustive MIPS (ground truth + within-cluster scans)
//! * [`kmeans`] — spherical k-means (coarse quantizer + dataset partitioner)
//! * [`ivf`] — FAISS-IVF-Flat analog: coarse cells + `nprobe` scan
//! * [`pq`] — product quantization (shared by scann)
//! * [`scann`] — ScaNN analog: IVF + *anisotropic* PQ scoring
//! * [`soar`] — SOAR analog: IVF with redundant spilled assignments
//! * [`leanvec`] — LeanVec analog: learned linear projection + IVF,
//!   full-dim rescoring

pub mod flat;
pub mod ivf;
pub mod kmeans;
pub mod leanvec;
pub mod pq;
pub mod scann;
pub mod soar;
pub mod traits;

pub use traits::{SearchCost, SearchResult, VectorIndex};
