//! Index substrates: every approximate-search backbone the paper
//! evaluates KeyNet against (Sec. 4.4, App. A.8), built from scratch.
//! All of them serve the typed [`crate::api::Searcher`] surface through
//! the [`VectorIndex`] trait.
//!
//! * [`flat`] — exhaustive MIPS (ground truth + within-cluster scans)
//! * [`kmeans`] — spherical k-means (coarse quantizer + dataset partitioner)
//! * [`ivf`] — FAISS-IVF-Flat analog: coarse cells + probed scan
//! * [`pq`] — product quantization codec + the flat `IndexPQ` analog
//! * [`sq`] — SQ8 scalar-quantized flat scan + exact re-rank
//! * [`scann`] — ScaNN analog: IVF + *anisotropic* PQ scoring
//! * [`soar`] — SOAR analog: IVF with redundant spilled assignments
//! * [`leanvec`] — LeanVec analog: learned linear projection + IVF,
//!   full-dim rescoring
//! * [`shard`] — sharded serving: any leaf backbone per key partition,
//!   fan-out search + global top-k merge (`sharded(shards=8,inner=...)`)
//! * [`segment`] — mutable collections: delta + sealed segments,
//!   tombstones, generation manifests, background compaction
//!
//! Construction goes through the typed [`spec::IndexSpec`] family
//! (`IndexSpec::build` is the one entry point; `--spec
//! "ivf(nlist=64)"` parses to it). Built indexes persist as versioned
//! binary artifacts ([`artifact`]: magic, version, backbone tag, spec
//! echo, checksum) and groups of them are served from a named
//! [`catalog::Catalog`] — build once, serve many.

pub mod artifact;
pub mod catalog;
pub mod flat;
pub mod ivf;
pub mod keystore;
pub mod kmeans;
pub mod leanvec;
pub mod pq;
pub mod scann;
pub mod segment;
pub mod shard;
pub mod soar;
pub mod spec;
pub mod sq;
pub mod traits;

pub use artifact::{load, load_from, save};
pub use catalog::{Catalog, CatalogEntry};
pub use keystore::{KeyStore, Storage};
pub use segment::{Compactor, CompactorConfig, MutableCollection};
pub use shard::ShardedIndex;
pub use spec::{
    auto_pq_m, leanvec_target_dim, BuildCtx, FlatSpec, IndexSpec, IvfSpec, LeanVecSpec, PqSpec,
    ScannSpec, ShardAssign, ShardedSpec, SoarSpec, SqSpec,
};
pub use traits::{SearchCost, SearchResult, VectorIndex};

use anyhow::Result;

use crate::tensor::Tensor;

/// The seven *leaf* index backbones served by the unified API. The
/// composite `"sharded"` backbone wraps any of these per key partition
/// (see [`shard`]) and is addressed through the spec grammar.
pub const BACKBONES: [&str; 7] = ["flat", "ivf", "pq", "sq8", "scann", "soar", "leanvec"];

/// Build any backbone by *name* with that backbone's default knobs — the
/// stringly construction path kept through the deprecation window. New
/// code should construct (or parse) a typed [`IndexSpec`] and call
/// [`IndexSpec::build`], which exposes every knob this shim freezes.
pub fn build_backend(
    name: &str,
    keys: &Tensor,
    sample_queries: Option<&Tensor>,
    nlist: usize,
    seed: u64,
) -> Result<Box<dyn VectorIndex>> {
    IndexSpec::default_for(name)?
        .with_nlist(nlist)
        .build(keys, &BuildCtx {
            sample_queries,
            seed,
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::normalize_rows;
    use crate::util::Rng;

    #[test]
    fn builds_every_backbone() {
        let mut keys = Tensor::zeros(&[200, 16]);
        Rng::new(1).fill_normal(keys.data_mut(), 1.0);
        normalize_rows(&mut keys);
        for name in BACKBONES {
            let idx = build_backend(name, &keys, None, 4, 7).unwrap();
            assert_eq!(idx.len(), 200, "{name}");
            assert_eq!(idx.dim(), 16, "{name}");
            assert!(idx.n_cells() >= 1, "{name}");
            assert_eq!(idx.spec().name(), name);
        }
        // the composite backbone builds through the same shim
        let idx = build_backend("sharded", &keys, None, 4, 7).unwrap();
        assert_eq!((idx.len(), idx.dim()), (200, 16));
        assert_eq!(idx.spec().name(), "sharded");
        assert!(build_backend("hnsw", &keys, None, 4, 7).is_err());
    }
}
