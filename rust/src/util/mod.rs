//! Small self-contained utilities (the offline environment vendors no
//! `rand`, `rayon` or logging crates — these modules replace them).

pub mod rng;
pub mod tempdir;
pub mod threads;
pub mod timer;

pub use rng::Rng;
pub use tempdir::TempDir;
pub use timer::Timer;

/// Case count for a seeded property sweep: the suite's fast `default`,
/// or the absolute count in `AMIPS_PROP_CASES` when set (the scheduled
/// CI deep sweep runs with `AMIPS_PROP_CASES=2000`). Lives in the
/// library (next to [`TempDir`]) so every test binary shares one
/// contract — sweeps are deterministic in the case index, so the same
/// env value reproduces the same cases everywhere.
pub fn prop_cases(default: usize) -> usize {
    std::env::var("AMIPS_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Suite-wide seed override for randomized tests: `AMIPS_TEST_SEED`
/// parsed as u64 (decimal, or hex with an `0x` prefix), 0 when unset
/// or unparseable. Every seeded test mixes this into its own fixed
/// per-test tag via [`test_rng`], so the default (unset ⇒ 0 ⇒ XOR is
/// the identity) reproduces the historical streams bit-for-bit while
/// one env var re-seeds the whole suite at once.
pub fn test_seed() -> u64 {
    std::env::var("AMIPS_TEST_SEED")
        .ok()
        .and_then(|v| {
            let v = v.trim();
            match v.strip_prefix("0x").or_else(|| v.strip_prefix("0X")) {
                Some(hex) => u64::from_str_radix(hex, 16).ok(),
                None => v.parse().ok(),
            }
        })
        .unwrap_or(0)
}

/// A test RNG derived from a fixed per-test `tag` XOR the suite-wide
/// [`test_seed`]. Prints the effective seed to stderr — captured by
/// the harness and therefore shown exactly when the test fails — so
/// any red randomized run is reproducible with
/// `AMIPS_TEST_SEED=<seed> cargo test <name>`.
pub fn test_rng(tag: u64) -> Rng {
    let seed = tag ^ test_seed();
    eprintln!("AMIPS_TEST_SEED effective seed: {seed:#x} (tag {tag:#x})");
    Rng::new(seed)
}
