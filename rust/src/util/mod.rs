//! Small self-contained utilities (the offline environment vendors no
//! `rand`, `rayon` or logging crates — these modules replace them).

pub mod rng;
pub mod threads;
pub mod timer;

pub use rng::Rng;
pub use timer::Timer;
