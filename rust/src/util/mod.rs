//! Small self-contained utilities (the offline environment vendors no
//! `rand`, `rayon` or logging crates — these modules replace them).

pub mod rng;
pub mod tempdir;
pub mod threads;
pub mod timer;

pub use rng::Rng;
pub use tempdir::TempDir;
pub use timer::Timer;

/// Case count for a seeded property sweep: the suite's fast `default`,
/// or the absolute count in `AMIPS_PROP_CASES` when set (the scheduled
/// CI deep sweep runs with `AMIPS_PROP_CASES=2000`). Lives in the
/// library (next to [`TempDir`]) so every test binary shares one
/// contract — sweeps are deterministic in the case index, so the same
/// env value reproduces the same cases everywhere.
pub fn prop_cases(default: usize) -> usize {
    std::env::var("AMIPS_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}
