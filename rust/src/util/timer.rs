//! Wall-clock timing + latency statistics used by Table 1 and every
//! latency-axis Pareto plot (no criterion offline; `bench_support`
//! builds the harness on these primitives).

use std::time::Instant;

/// Simple stopwatch.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

/// Run `f` `reps` times after `warmup` runs; returns per-rep seconds.
pub fn time_reps<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..reps)
        .map(|_| {
            let t = Timer::start();
            f();
            t.elapsed_s()
        })
        .collect()
}

/// Summary statistics over a sample of seconds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Stats {
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Stats {
    pub fn from(samples: &[f64]) -> Stats {
        assert!(!samples.is_empty());
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| sorted[((p * (sorted.len() - 1) as f64).round() as usize).min(sorted.len() - 1)];
        Stats {
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: q(0.5),
            p95: q(0.95),
            max: *sorted.last().unwrap(),
        }
    }
}

/// Online latency histogram with fixed log-spaced buckets (1us..10s),
/// allocation-free on the record path — used by the serving coordinator.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum_s: f64,
    max_s: f64,
}

const HIST_BUCKETS: usize = 64;

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: vec![0; HIST_BUCKETS],
            count: 0,
            sum_s: 0.0,
            max_s: 0.0,
        }
    }

    #[inline]
    fn bucket_of(s: f64) -> usize {
        // log10 from 1e-6 .. 10 s over 64 buckets
        let l = (s.max(1e-6)).log10(); // in [-6, ...]
        (((l + 6.0) / 7.0 * HIST_BUCKETS as f64) as usize).min(HIST_BUCKETS - 1)
    }

    #[inline]
    pub fn record(&mut self, seconds: f64) {
        self.buckets[Self::bucket_of(seconds)] += 1;
        self.count += 1;
        self.sum_s += seconds;
        if seconds > self.max_s {
            self.max_s = seconds;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }
    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_s / self.count as f64
        }
    }

    /// Largest recorded value in seconds (0 when empty).
    pub fn max_s(&self) -> f64 {
        self.max_s
    }

    /// Median latency (bucket-midpoint approximation).
    pub fn p50_s(&self) -> f64 {
        self.quantile_s(0.5)
    }

    /// 99th-percentile latency.
    pub fn p99_s(&self) -> f64 {
        self.quantile_s(0.99)
    }

    /// 99.9th-percentile latency — the serving tail the net front-end
    /// reports in its `Stats` frame.
    pub fn p999_s(&self) -> f64 {
        self.quantile_s(0.999)
    }

    /// Cheap point-in-time copy (64 counters + 3 scalars, no
    /// allocation churn beyond one `Vec` clone). Per-connection
    /// histograms snapshot under their own lock and [`merge`] into a
    /// server-wide roll-up without holding every lock at once.
    ///
    /// [`merge`]: LatencyHistogram::merge
    pub fn snapshot(&self) -> LatencyHistogram {
        self.clone()
    }

    /// Approximate quantile from bucket midpoints.
    pub fn quantile_s(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                let mid = (i as f64 + 0.5) / HIST_BUCKETS as f64 * 7.0 - 6.0;
                return 10f64.powf(mid);
            }
        }
        self.max_s
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_s += other.sum_s;
        self.max_s = self.max_s.max(other.max_s);
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-5); // 10us .. 10ms
        }
        assert_eq!(h.count(), 1000);
        let (p50, p95) = (h.quantile_s(0.5), h.quantile_s(0.95));
        assert!(p50 < p95);
        assert!(p50 > 1e-3 && p50 < 1e-2, "{p50}");
        assert!((h.mean_s() - 5.0e-3).abs() < 1e-3);
    }

    #[test]
    fn histogram_quantiles_track_sorted_reference() {
        // the histogram is log-bucketed (64 buckets over 7 decades →
        // ~1.29x bucket width), so each quantile must land within one
        // bucket ratio of the exact sorted-sample quantile
        let mut rng = crate::util::Rng::new(0xBEEF);
        let mut h = LatencyHistogram::new();
        let mut samples = Vec::new();
        for _ in 0..5000 {
            // log-uniform over 10us..100ms: exercises many buckets
            let s = 10f64.powf(-5.0 + 4.0 * rng.uniform());
            h.record(s);
            samples.push(s);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let bucket_ratio = 10f64.powf(7.0 / 64.0); // ~1.286
        for q in [0.5, 0.9, 0.99, 0.999] {
            let exact = samples[((q * (samples.len() - 1) as f64).round() as usize)
                .min(samples.len() - 1)];
            let approx = h.quantile_s(q);
            let ratio = approx / exact;
            assert!(
                ratio > 1.0 / (bucket_ratio * bucket_ratio)
                    && ratio < bucket_ratio * bucket_ratio,
                "q={q}: approx {approx} vs exact {exact} (ratio {ratio})"
            );
        }
        // named accessors agree with quantile_s
        assert_eq!(h.p50_s(), h.quantile_s(0.5));
        assert_eq!(h.p99_s(), h.quantile_s(0.99));
        assert_eq!(h.p999_s(), h.quantile_s(0.999));
        // quantiles are monotone and bounded by the recorded max
        assert!(h.p50_s() <= h.p99_s());
        assert!(h.p99_s() <= h.p999_s());
        assert!(h.p999_s() <= h.max_s() * bucket_ratio);
        assert_eq!(h.max_s(), *samples.last().unwrap());
    }

    #[test]
    fn snapshot_then_merge_rolls_up() {
        // per-connection pattern: snapshot two live histograms, merge
        // into a roll-up; counts and extremes add up, originals intact
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for i in 1..=100 {
            a.record(i as f64 * 1e-5);
            b.record(i as f64 * 1e-4);
        }
        let mut roll = LatencyHistogram::new();
        roll.merge(&a.snapshot());
        roll.merge(&b.snapshot());
        assert_eq!(roll.count(), 200);
        assert_eq!(roll.max_s(), b.max_s());
        assert!((roll.mean_s() - (a.mean_s() + b.mean_s()) / 2.0).abs() < 1e-12);
        // merging a snapshot leaves the source untouched
        assert_eq!(a.count(), 100);
        // empty histogram reports zeros, not NaN
        let empty = LatencyHistogram::new();
        assert_eq!(empty.p999_s(), 0.0);
        assert_eq!(empty.max_s(), 0.0);
    }

    #[test]
    fn histogram_merge_adds() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(1e-3);
        b.record(2e-3);
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn time_reps_counts() {
        let v = time_reps(1, 5, || {
            std::hint::black_box(0);
        });
        assert_eq!(v.len(), 5);
        assert!(v.iter().all(|&s| s >= 0.0));
    }
}
