//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64, plus Gaussian
//! sampling (Box–Muller). No external crates are available offline, and a
//! fully deterministic generator is a feature here anyway: every dataset,
//! clustering and augmentation in the repo is reproducible from a u64 seed.

/// xoshiro256++ (Blackman & Vigna). Passes BigCrush; 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller output.
    spare: Option<f64>,
}

#[inline(always)]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent child stream (for per-shard determinism).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline(always)]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3]))
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline(always)]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u32 in [0, bound) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0 && bound <= u32::MAX as usize);
        let b = bound as u64;
        let mut x = self.next_u64() & 0xFFFF_FFFF;
        let mut m = x * b;
        let mut l = m & 0xFFFF_FFFF;
        if l < b {
            let t = (u32::MAX as u64 + 1 - b) % b;
            while l < t {
                x = self.next_u64() & 0xFFFF_FFFF;
                m = x * b;
                l = m & 0xFFFF_FFFF;
            }
        }
        (m >> 32) as usize
    }

    /// Standard normal via Box–Muller (caches the paired sample).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        // u1 in (0,1] to keep ln finite.
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fill a slice with i.i.d. N(0, std^2) f32 samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() as f32 * std;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..20_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / 20_000.0 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m1 += z;
            m2 += z * z;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.03, "var {m2}");
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(4);
        let idx = r.sample_indices(100, 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
