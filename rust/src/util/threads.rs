//! Minimal data-parallel helpers on `std::thread::scope` (no rayon
//! offline). On this single-core testbed `parallel_for` degrades to a
//! plain loop; the code is still structured for multi-core so the repo
//! runs at full width elsewhere.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

thread_local! {
    /// Set on `parallel_chunks` worker threads for their whole lifetime
    /// (workers are spawned fresh per call, so it is never reset).
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// True when the current thread is a `parallel_chunks` worker. Nested
/// data-parallel code (e.g. the sharded fan-out inside a batched
/// search) checks this to degrade to a sequential loop instead of
/// spawning workers-of-workers and oversubscribing the cores.
pub fn in_parallel_region() -> bool {
    IN_POOL.with(Cell::get)
}

/// Number of worker threads to use (respects `AMIPS_THREADS`).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("AMIPS_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run `f(chunk_index, start, end)` over `n` items split into contiguous
/// chunks, one in-flight chunk per worker, work-stealing via an atomic
/// cursor. `f` must be `Sync` (called concurrently).
pub fn parallel_chunks<F>(n: usize, chunk: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let workers = num_threads();
    let chunk = chunk.max(1);
    // Never spawn workers-of-workers: a nested call from inside a pool
    // worker (e.g. a fused kernel running on a sub-batch) degrades to
    // the sequential loop instead of oversubscribing the cores.
    if workers <= 1 || n <= chunk || in_parallel_region() {
        let mut start = 0;
        let mut i = 0;
        while start < n {
            let end = (start + chunk).min(n);
            f(i, start, end);
            start = end;
            i += 1;
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    let nchunks = n.div_ceil(chunk);
    std::thread::scope(|s| {
        for _ in 0..workers.min(nchunks) {
            s.spawn(|| {
                IN_POOL.with(|flag| flag.set(true));
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= nchunks {
                        break;
                    }
                    let start = i * chunk;
                    let end = (start + chunk).min(n);
                    f(i, start, end);
                }
            });
        }
    });
}

/// Map `f` over disjoint mutable row-chunks of `out` (rows of width
/// `row_w`), passing the global row range. The classic "split a matrix by
/// rows across workers" pattern without unsafe at call sites.
pub fn parallel_rows_mut<F>(out: &mut [f32], row_w: usize, rows_per_task: usize, f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    assert_eq!(out.len() % row_w.max(1), 0);
    let n_rows = if row_w == 0 { 0 } else { out.len() / row_w };
    let workers = num_threads();
    // same nesting guard as `parallel_chunks`: gemm_nt inside a pool
    // worker must not spawn a second tier of threads
    if workers <= 1 || n_rows <= rows_per_task || in_parallel_region() {
        for (i, chunk_rows) in out.chunks_mut(rows_per_task.max(1) * row_w).enumerate() {
            let start = i * rows_per_task;
            let end = start + chunk_rows.len() / row_w;
            f(start, end, chunk_rows);
        }
        return;
    }
    std::thread::scope(|s| {
        let mut rest = out;
        let mut start = 0;
        while !rest.is_empty() {
            let take = (rows_per_task * row_w).min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let end = start + take / row_w;
            let fref = &f;
            s.spawn(move || fref(start, end, head));
            rest = tail;
            start = end;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_all_items_once() {
        let n = 1003;
        let seen = (0..n).map(|_| AtomicUsize::new(0)).collect::<Vec<_>>();
        parallel_chunks(n, 17, |_, s, e| {
            for i in s..e {
                seen[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(seen.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn in_parallel_region_flags_pool_workers_only() {
        assert!(!in_parallel_region());
        let flagged = AtomicUsize::new(0);
        parallel_chunks(64, 1, |_, _, _| {
            if in_parallel_region() {
                flagged.fetch_add(1, Ordering::Relaxed);
            }
        });
        // pool workers see the flag; the sequential fallback (single
        // worker) runs on the caller thread and must not
        if num_threads() > 1 {
            assert_eq!(flagged.load(Ordering::Relaxed), 64);
        } else {
            assert_eq!(flagged.load(Ordering::Relaxed), 0);
        }
        assert!(!in_parallel_region());
    }

    #[test]
    fn nested_parallel_calls_degrade_to_sequential() {
        // a parallel_chunks call from inside a pool worker must run on
        // that worker thread (no second tier of spawns) — the inner
        // callback still sees the pool flag
        let inner_on_pool = AtomicUsize::new(0);
        let inner_total = AtomicUsize::new(0);
        parallel_chunks(8, 1, |_, _, _| {
            parallel_chunks(4, 1, |_, _, _| {
                inner_total.fetch_add(1, Ordering::Relaxed);
                if in_parallel_region() {
                    inner_on_pool.fetch_add(1, Ordering::Relaxed);
                }
            });
        });
        assert_eq!(inner_total.load(Ordering::Relaxed), 8 * 4);
        if num_threads() > 1 {
            assert_eq!(inner_on_pool.load(Ordering::Relaxed), 8 * 4);
        }
    }

    #[test]
    fn chunks_handle_empty_and_single() {
        parallel_chunks(0, 8, |_, _, _| panic!("no work expected"));
        let hits = AtomicU64::new(0);
        parallel_chunks(1, 8, |_, s, e| {
            assert_eq!((s, e), (0, 1));
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn rows_mut_writes_disjoint() {
        let mut out = vec![0.0f32; 10 * 4];
        parallel_rows_mut(&mut out, 4, 3, |start, end, chunk| {
            assert_eq!(chunk.len(), (end - start) * 4);
            for (r, row) in chunk.chunks_mut(4).enumerate() {
                row.fill((start + r) as f32);
            }
        });
        for r in 0..10 {
            assert!(out[r * 4..r * 4 + 4].iter().all(|&v| v == r as f32));
        }
    }
}
