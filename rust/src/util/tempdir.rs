//! Unique temporary directories with cleanup-on-drop, for tests and
//! examples that persist artifacts/catalogs. The old pattern —
//! `std::env::temp_dir().join(format!("...-{pid}"))` — collides when
//! two tests in one binary share a prefix; `TempDir` paths are keyed by
//! (prefix, pid, per-process counter), so every handle in a process is
//! distinct and concurrent test binaries cannot clash. The directory is
//! deleted on drop (including during unwinding, so a failed assertion
//! doesn't leak state into the next run); a leftover at the same path —
//! possible only when a hard-killed run's pid is recycled — is wiped on
//! creation.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static NEXT_ID: AtomicU64 = AtomicU64::new(0);

/// A freshly-created directory under the system temp dir, removed
/// (recursively) when the handle drops.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create `<tmp>/<prefix>-<pid>-<counter>`, wiping any stale
    /// leftover directory at that path first.
    pub fn new(prefix: &str) -> TempDir {
        let id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
        Self::create_at(std::env::temp_dir().join(format!("{prefix}-{}-{id}", std::process::id())))
    }

    /// Wipe-then-create at an explicit path (the uniqueness of the path
    /// is the caller's problem; [`TempDir::new`] derives a unique one).
    fn create_at(path: PathBuf) -> TempDir {
        std::fs::remove_dir_all(&path).ok();
        std::fs::create_dir_all(&path)
            .unwrap_or_else(|e| panic!("creating temp dir {}: {e}", path.display()));
        TempDir { path }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// A path inside the directory.
    pub fn join(&self, name: &str) -> PathBuf {
        self.path.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.path).ok();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unique_created_and_removed_on_drop() {
        let a = TempDir::new("amips-tempdir-test");
        let b = TempDir::new("amips-tempdir-test");
        assert_ne!(a.path(), b.path());
        assert!(a.path().is_dir() && b.path().is_dir());
        std::fs::write(a.join("f.txt"), b"x").unwrap();
        let (pa, pb) = (a.path().to_path_buf(), b.path().to_path_buf());
        drop(a);
        drop(b);
        assert!(!pa.exists(), "{}", pa.display());
        assert!(!pb.exists(), "{}", pb.display());
    }

    #[test]
    fn stale_leftover_at_same_path_is_wiped() {
        // simulate a hard-killed earlier run whose pid got recycled:
        // stale content already sits at the path create_at will claim
        let path = std::env::temp_dir().join(format!(
            "amips-tempdir-stale-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(path.join("stale-sub")).unwrap();
        let fresh = TempDir::create_at(path.clone());
        assert_eq!(fresh.path(), path);
        assert!(!fresh.join("stale-sub").exists());
    }
}
