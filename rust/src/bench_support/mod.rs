//! Shared benchmark harness (criterion is unavailable offline): Pareto
//! sweeps, aligned table reports, and the common experiment fixtures the
//! per-figure benches reuse. Every bench binary prints the rows/series
//! the corresponding paper table/figure reports and appends them to
//! `bench_results/`.

pub mod fixtures;
pub mod pareto;
pub mod report;

pub use pareto::{pareto_front, ParetoPoint};
pub use report::Report;
