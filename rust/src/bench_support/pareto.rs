//! Accuracy-vs-cost Pareto utilities for the figure reproductions.

/// One operating point on a cost/quality trade-off curve.
#[derive(Clone, Debug, PartialEq)]
pub struct ParetoPoint {
    pub label: String,
    /// cost (flops, probes, seconds, …) — lower is better
    pub cost: f64,
    /// quality (accuracy/recall) — higher is better
    pub value: f64,
}

/// Non-dominated subset, sorted by ascending cost. A point dominates
/// another if it is no worse on both axes and better on one.
pub fn pareto_front(points: &[ParetoPoint]) -> Vec<ParetoPoint> {
    let mut sorted: Vec<ParetoPoint> = points.to_vec();
    sorted.sort_by(|a, b| {
        a.cost
            .partial_cmp(&b.cost)
            .unwrap()
            .then(b.value.partial_cmp(&a.value).unwrap())
    });
    let mut front: Vec<ParetoPoint> = Vec::new();
    let mut best = f64::NEG_INFINITY;
    for p in sorted {
        if p.value > best {
            best = p.value;
            front.push(p);
        }
    }
    front
}

/// Area-under-curve style summary: mean value of the front over log-cost
/// (used to compare methods in one number per figure).
pub fn front_score(front: &[ParetoPoint]) -> f64 {
    if front.is_empty() {
        return 0.0;
    }
    front.iter().map(|p| p.value).sum::<f64>() / front.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(cost: f64, value: f64) -> ParetoPoint {
        ParetoPoint {
            label: String::new(),
            cost,
            value,
        }
    }

    #[test]
    fn removes_dominated_points() {
        let front = pareto_front(&[p(1.0, 0.5), p(2.0, 0.4), p(3.0, 0.9)]);
        assert_eq!(front.len(), 2);
        assert_eq!(front[0].cost, 1.0);
        assert_eq!(front[1].cost, 3.0);
    }

    #[test]
    fn keeps_strictly_improving_chain() {
        let front = pareto_front(&[p(1.0, 0.1), p(2.0, 0.2), p(3.0, 0.3)]);
        assert_eq!(front.len(), 3);
    }

    #[test]
    fn equal_cost_keeps_best_value() {
        let front = pareto_front(&[p(1.0, 0.2), p(1.0, 0.8)]);
        assert_eq!(front.len(), 1);
        assert_eq!(front[0].value, 0.8);
    }

    #[test]
    fn empty_ok() {
        assert!(pareto_front(&[]).is_empty());
        assert_eq!(front_score(&[]), 0.0);
    }
}
