//! Shared experiment fixtures: dataset preparation from the manifest and
//! (behind the `xla` feature) train-or-load model acquisition. Used by
//! the CLI, the examples and every bench so all of them agree on seeds
//! and scaling.

use anyhow::Result;

use crate::data::{dataset::PrepareOpts, Dataset};
use crate::runtime::Manifest;

/// Load the artifacts manifest (run `make artifacts` first).
pub fn load_manifest() -> Result<Manifest> {
    Manifest::load(&crate::artifacts_dir())
}

/// Augmentation factor targeting ~10k train queries (paper: 5–100x,
/// scaled to corpus size).
pub fn augment_factor(base_queries: usize) -> usize {
    (10_000 / base_queries.max(1)).clamp(1, 8)
}

/// Prepare a dataset by manifest name with `c` clusters.
pub fn prepare_dataset(manifest: &Manifest, name: &str, c: usize) -> Result<Dataset> {
    let spec = manifest.dataset(name)?.to_corpus_spec();
    let base = spec.n_queries.saturating_sub(manifest.val_queries).max(1);
    let opts = PrepareOpts {
        c,
        augment: augment_factor(base),
        aug_sigma: manifest.aug_sigma,
        val_queries: manifest.val_queries,
        kmeans_restarts: 3,
        seed: spec.seed ^ 0xDA7A,
    };
    Ok(Dataset::prepare(&spec, &opts))
}

/// Default step budget for a config, scaled by model size so benches
/// stay tractable on the single-core testbed.
pub fn default_steps(size: &str) -> usize {
    match size {
        "xs" => 4000,
        "s" => 4000,
        "m" => 3000,
        "l" => 2000,
        _ => 2500,
    }
}

/// IVF cell count heuristic (~sqrt(n), the classic FAISS guidance).
pub fn default_nlist(n_keys: usize) -> usize {
    ((n_keys as f64).sqrt().round() as usize).clamp(4, 512)
}

/// Train (or load the cached checkpoint of) `config` on `ds`, returning
/// a ready inference handle.
#[cfg(feature = "xla")]
pub fn trained_model(
    engine: &crate::runtime::Engine,
    manifest: &Manifest,
    config: &str,
    ds: &Dataset,
    opts: Option<crate::trainer::TrainOpts>,
) -> Result<crate::model::AmortizedModel> {
    use crate::trainer::{self, TrainOpts};
    let meta = manifest.meta(config)?;
    let opts = opts.unwrap_or_else(|| TrainOpts {
        steps: default_steps(&meta.size),
        ..TrainOpts::default()
    });
    let out = trainer::train_or_load(engine, &meta, ds, &opts)?;
    crate::model::AmortizedModel::load(engine, meta, &out.params)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn augment_factor_bounds() {
        assert_eq!(augment_factor(100_000), 1);
        assert_eq!(augment_factor(1), 8);
        assert!(augment_factor(2000) >= 1);
    }

    #[test]
    fn default_steps_by_size() {
        assert!(default_steps("xs") >= default_steps("l"));
    }
}
