//! Shared experiment fixtures: dataset preparation from the manifest and
//! (behind the `xla` feature) train-or-load model acquisition. Used by
//! the CLI, the examples and every bench so all of them agree on seeds
//! and scaling.

use anyhow::Result;

use crate::data::{dataset::PrepareOpts, CorpusSpec, Dataset};
use crate::runtime::Manifest;

/// Load the artifacts manifest (run `make artifacts` first).
pub fn load_manifest() -> Result<Manifest> {
    Manifest::load(&crate::artifacts_dir())
}

/// The synthetic corpus used by the pure-Rust CLI verbs (`amips search |
/// train | eval`) — one shared definition so an index built by `amips
/// build` and a mapper trained by `amips train` with the same
/// `(n, d, seed)` see the same keys and query distribution.
pub fn synth_corpus_spec(n_keys: usize, d: usize, n_queries: usize, seed: u64) -> CorpusSpec {
    CorpusSpec {
        name: format!("synth-{n_keys}x{d}"),
        n_keys,
        d,
        n_queries,
        shift: 0.5,
        spread: 2.0,
        modes: 12,
        seed,
    }
}

/// Just the shared synthetic key set for `(n, d, seed)` — what `amips
/// build` indexes. The generator draws keys before queries from one
/// seeded stream, so these are byte-identical to the keys inside
/// [`synth_dataset`] regardless of the query count.
pub fn synth_keys(n_keys: usize, d: usize, seed: u64) -> crate::tensor::Tensor {
    crate::data::SynthCorpus::generate(&synth_corpus_spec(n_keys, d, 0, seed)).keys
}

/// Prepare the shared synthetic dataset: `val_queries` held out, the
/// rest augmented toward ~10k train queries.
pub fn synth_dataset(n_keys: usize, d: usize, val_queries: usize, c: usize, seed: u64) -> Dataset {
    let spec = synth_corpus_spec(n_keys, d, val_queries * 4, seed);
    Dataset::prepare(
        &spec,
        &PrepareOpts {
            c,
            augment: augment_factor(val_queries * 3),
            val_queries,
            kmeans_restarts: 1,
            ..Default::default()
        },
    )
}

/// The paper-analog dataset table (mirrors `python/compile/manifest.py`)
/// so benches run in the default build even when `make artifacts` never
/// ran. Returns `None` for unknown names.
pub fn builtin_dataset_spec(name: &str) -> Option<crate::runtime::artifact::DatasetSpec> {
    let (n, d, n_queries, shift, spread, modes, seed) = match name {
        "fiqa-s" => (2048, 64, 4096, 0.30, 6.0, 12, 101),
        "quora-s" => (6144, 64, 8192, 0.08, 1.6, 16, 102),
        "nq-s" => (16384, 64, 16384, 0.45, 7.0, 24, 103),
        "hotpot-s" => (32768, 64, 16384, 0.42, 7.0, 32, 104),
        "bioasq-s" => (65536, 64, 12288, 0.42, 7.0, 40, 105),
        "nq-s-d128" => (16384, 128, 8192, 0.45, 7.0, 24, 106),
        _ => return None,
    };
    Some(crate::runtime::artifact::DatasetSpec {
        name: name.to_string(),
        n,
        d,
        n_queries,
        shift,
        spread,
        modes,
        seed,
    })
}

/// Prepare a dataset by name: from the artifacts manifest when present,
/// else from the built-in paper-analog table — the pure-Rust benches'
/// entry point.
pub fn prepare_dataset_or_builtin(name: &str, c: usize) -> Result<Dataset> {
    if let Ok(manifest) = load_manifest() {
        if manifest.dataset(name).is_ok() {
            return prepare_dataset(&manifest, name, c);
        }
    }
    let spec = builtin_dataset_spec(name)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{name}' (no manifest, no builtin)"))?
        .to_corpus_spec();
    let base = spec.n_queries.saturating_sub(1000).max(1);
    let opts = PrepareOpts {
        c,
        augment: augment_factor(base),
        aug_sigma: 0.02,
        val_queries: 1000,
        kmeans_restarts: 3,
        seed: spec.seed ^ 0xDA7A,
    };
    Ok(Dataset::prepare(&spec, &opts))
}

/// Augmentation factor targeting ~10k train queries (paper: 5–100x,
/// scaled to corpus size).
pub fn augment_factor(base_queries: usize) -> usize {
    (10_000 / base_queries.max(1)).clamp(1, 8)
}

/// Prepare a dataset by manifest name with `c` clusters.
pub fn prepare_dataset(manifest: &Manifest, name: &str, c: usize) -> Result<Dataset> {
    let spec = manifest.dataset(name)?.to_corpus_spec();
    let base = spec.n_queries.saturating_sub(manifest.val_queries).max(1);
    let opts = PrepareOpts {
        c,
        augment: augment_factor(base),
        aug_sigma: manifest.aug_sigma,
        val_queries: manifest.val_queries,
        kmeans_restarts: 3,
        seed: spec.seed ^ 0xDA7A,
    };
    Ok(Dataset::prepare(&spec, &opts))
}

/// Default step budget for a config, scaled by model size so benches
/// stay tractable on the single-core testbed.
pub fn default_steps(size: &str) -> usize {
    match size {
        "xs" => 4000,
        "s" => 4000,
        "m" => 3000,
        "l" => 2000,
        _ => 2500,
    }
}

/// IVF cell count heuristic (~sqrt(n), the classic FAISS guidance).
pub fn default_nlist(n_keys: usize) -> usize {
    ((n_keys as f64).sqrt().round() as usize).clamp(4, 512)
}

/// Train (or load the cached checkpoint of) `config` on `ds`, returning
/// a ready inference handle.
#[cfg(feature = "xla")]
pub fn trained_model(
    engine: &crate::runtime::Engine,
    manifest: &Manifest,
    config: &str,
    ds: &Dataset,
    opts: Option<crate::trainer::TrainOpts>,
) -> Result<crate::model::XlaModel> {
    use crate::trainer::{self, TrainOpts};
    let meta = manifest.meta(config)?;
    let opts = opts.unwrap_or_else(|| TrainOpts {
        steps: default_steps(&meta.size),
        ..TrainOpts::default()
    });
    let out = trainer::train_or_load(engine, &meta, ds, &opts)?;
    crate::model::XlaModel::load(engine, meta, &out.params)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn augment_factor_bounds() {
        assert_eq!(augment_factor(100_000), 1);
        assert_eq!(augment_factor(1), 8);
        assert!(augment_factor(2000) >= 1);
    }

    #[test]
    fn default_steps_by_size() {
        assert!(default_steps("xs") >= default_steps("l"));
    }

    #[test]
    fn synth_keys_match_dataset_keys_regardless_of_query_count() {
        // the `amips build` / `amips train` key-consistency contract:
        // same (n, d, seed) => byte-identical keys, whatever the query
        // count of either side
        let ks = synth_keys(300, 8, 5);
        let ds = synth_dataset(300, 8, 40, 1, 5);
        assert_eq!(ks.data(), ds.keys.data());
        let ds2 = synth_dataset(300, 8, 80, 1, 5);
        assert_eq!(ks.data(), ds2.keys.data());
    }

    #[test]
    fn builtin_specs_cover_the_bench_datasets() {
        for name in ["quora-s", "nq-s", "hotpot-s"] {
            let spec = builtin_dataset_spec(name).unwrap();
            assert_eq!(spec.name, name);
            assert!(spec.n > 0 && spec.d > 0);
        }
        assert!(builtin_dataset_spec("nope").is_none());
    }
}
