//! Aligned-table reports: every bench prints its paper-figure rows and
//! appends the same text to `bench_results/<bench>.txt` so EXPERIMENTS.md
//! can cite stable outputs.

use std::fmt::Write as _;
use std::io::Write as _;

/// A simple column-aligned text table + free-form notes.
pub struct Report {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    notes: Vec<String>,
}

impl Report {
    pub fn new(title: &str) -> Report {
        Report {
            title: title.to_string(),
            header: Vec::new(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn header(&mut self, cols: &[&str]) -> &mut Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cols: &[String]) -> &mut Self {
        self.rows.push(cols.to_vec());
        self
    }

    pub fn note(&mut self, s: impl Into<String>) -> &mut Self {
        self.notes.push(s.into());
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                if i >= widths.len() {
                    widths.push(cell.len());
                } else {
                    widths[i] = widths[i].max(cell.len());
                }
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        if !self.header.is_empty() {
            let line: Vec<String> = self
                .header
                .iter()
                .enumerate()
                .map(|(i, h)| format!("{h:>w$}", w = widths[i]))
                .collect();
            let _ = writeln!(out, "{}", line.join("  "));
            let _ = writeln!(
                out,
                "{}",
                widths
                    .iter()
                    .map(|w| "-".repeat(*w))
                    .collect::<Vec<_>>()
                    .join("  ")
            );
        }
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{c:>w$}", w = widths.get(i).copied().unwrap_or(c.len())))
                .collect();
            let _ = writeln!(out, "{}", line.join("  "));
        }
        for n in &self.notes {
            let _ = writeln!(out, "note: {n}");
        }
        out
    }

    /// Print to stdout and append to bench_results/<file>.txt.
    pub fn emit(&self, file: &str) {
        let text = self.render();
        println!("{text}");
        let dir = std::path::Path::new("bench_results");
        if std::fs::create_dir_all(dir).is_ok() {
            if let Ok(mut f) = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(dir.join(format!("{file}.txt")))
            {
                let _ = writeln!(f, "{text}");
            }
        }
    }
}

/// One typed JSON value for [`JsonRows`] (no serde offline; the tiny
/// subset the bench trajectory needs, with escaping and non-finite
/// floats mapped to `null`).
pub enum JsonVal {
    S(String),
    F(f64),
    I(u64),
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

impl JsonVal {
    fn render(&self) -> String {
        match self {
            JsonVal::S(s) => format!("\"{}\"", json_escape(s)),
            JsonVal::F(v) if v.is_finite() => format!("{v}"),
            JsonVal::F(_) => "null".to_string(),
            JsonVal::I(v) => format!("{v}"),
        }
    }
}

/// Machine-readable bench output: a flat array of uniform row objects,
/// written as `BENCH_<name>.json` in the working directory so the bench
/// trajectory can be tracked across commits (the aligned-text
/// [`Report`]s stay the human-readable channel).
pub struct JsonRows {
    bench: String,
    rows: Vec<String>,
}

impl JsonRows {
    pub fn new(bench: &str) -> JsonRows {
        JsonRows {
            bench: bench.to_string(),
            rows: Vec::new(),
        }
    }

    /// Append one row object from (key, value) pairs (order preserved).
    pub fn push(&mut self, fields: &[(&str, JsonVal)]) {
        let body: Vec<String> = fields
            .iter()
            .map(|(k, v)| format!("\"{}\": {}", json_escape(k), v.render()))
            .collect();
        self.rows.push(format!("{{{}}}", body.join(", ")));
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{{");
        let _ = writeln!(out, "  \"bench\": \"{}\",", json_escape(&self.bench));
        let _ = writeln!(out, "  \"rows\": [");
        for (i, row) in self.rows.iter().enumerate() {
            let comma = if i + 1 == self.rows.len() { "" } else { "," };
            let _ = writeln!(out, "    {row}{comma}");
        }
        let _ = writeln!(out, "  ]");
        out.push('}');
        out.push('\n');
        out
    }

    /// Write (overwrite, not append: the file reflects one run) to
    /// `BENCH_<bench>.json` in the current directory.
    pub fn emit(&self) {
        let path = format!("BENCH_{}.json", self.bench);
        match std::fs::write(&path, self.render()) {
            Ok(()) => println!("wrote {} rows to {path}", self.rows.len()),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}

/// Format helpers shared by benches.
pub fn f(v: f64) -> String {
    format!("{v:.4}")
}

pub fn e(v: f64) -> String {
    format!("{v:.3e}")
}

pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut r = Report::new("t");
        r.header(&["a", "long-col"]);
        r.row(&["1".into(), "2".into()]);
        r.row(&["100".into(), "20000".into()]);
        let text = r.render();
        assert!(text.contains("== t =="));
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 5);
        // all data lines same length
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    fn formats() {
        assert_eq!(f(0.12345), "0.1235"); // round-half-up
        assert_eq!(pct(0.5), "50.0%");
        assert!(e(12345.0).contains('e'));
    }

    #[test]
    fn json_rows_render_valid_structure() {
        let mut j = JsonRows::new("unit");
        j.push(&[
            ("backend", JsonVal::S("ivf".into())),
            ("recall", JsonVal::F(0.93)),
            ("nprobe", JsonVal::I(4)),
            ("nan", JsonVal::F(f64::NAN)),
        ]);
        j.push(&[("backend", JsonVal::S("weird \"name\"\n".into()))]);
        assert_eq!(j.len(), 2);
        let text = j.render();
        assert!(text.contains("\"bench\": \"unit\""));
        assert!(text.contains("\"recall\": 0.93"));
        assert!(text.contains("\"nan\": null"));
        assert!(text.contains("weird \\\"name\\\"\\n"));
        // rows are comma-separated, last one bare
        assert_eq!(text.matches("},").count(), 1);
        // balanced braces: one object wrapper + two rows
        assert_eq!(text.matches('{').count(), 3);
        assert_eq!(text.matches('}').count(), 3);
    }
}
