//! Aligned-table reports: every bench prints its paper-figure rows and
//! appends the same text to `bench_results/<bench>.txt` so EXPERIMENTS.md
//! can cite stable outputs.

use std::fmt::Write as _;
use std::io::Write as _;

/// A simple column-aligned text table + free-form notes.
pub struct Report {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    notes: Vec<String>,
}

impl Report {
    pub fn new(title: &str) -> Report {
        Report {
            title: title.to_string(),
            header: Vec::new(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn header(&mut self, cols: &[&str]) -> &mut Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cols: &[String]) -> &mut Self {
        self.rows.push(cols.to_vec());
        self
    }

    pub fn note(&mut self, s: impl Into<String>) -> &mut Self {
        self.notes.push(s.into());
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                if i >= widths.len() {
                    widths.push(cell.len());
                } else {
                    widths[i] = widths[i].max(cell.len());
                }
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        if !self.header.is_empty() {
            let line: Vec<String> = self
                .header
                .iter()
                .enumerate()
                .map(|(i, h)| format!("{h:>w$}", w = widths[i]))
                .collect();
            let _ = writeln!(out, "{}", line.join("  "));
            let _ = writeln!(
                out,
                "{}",
                widths
                    .iter()
                    .map(|w| "-".repeat(*w))
                    .collect::<Vec<_>>()
                    .join("  ")
            );
        }
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{c:>w$}", w = widths.get(i).copied().unwrap_or(c.len())))
                .collect();
            let _ = writeln!(out, "{}", line.join("  "));
        }
        for n in &self.notes {
            let _ = writeln!(out, "note: {n}");
        }
        out
    }

    /// Print to stdout and append to bench_results/<file>.txt.
    pub fn emit(&self, file: &str) {
        let text = self.render();
        println!("{text}");
        let dir = std::path::Path::new("bench_results");
        if std::fs::create_dir_all(dir).is_ok() {
            if let Ok(mut f) = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(dir.join(format!("{file}.txt")))
            {
                let _ = writeln!(f, "{text}");
            }
        }
    }
}

/// Format helpers shared by benches.
pub fn f(v: f64) -> String {
    format!("{v:.4}")
}

pub fn e(v: f64) -> String {
    format!("{v:.3e}")
}

pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut r = Report::new("t");
        r.header(&["a", "long-col"]);
        r.row(&["1".into(), "2".into()]);
        r.row(&["100".into(), "20000".into()]);
        let text = r.render();
        assert!(text.contains("== t =="));
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 5);
        // all data lines same length
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    fn formats() {
        assert_eq!(f(0.12345), "0.1235"); // round-half-up
        assert_eq!(pct(0.5), "50.0%");
        assert!(e(12345.0).contains('e'));
    }
}
