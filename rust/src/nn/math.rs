//! Small dense matmul helpers for the nn layer's forward passes and tape
//! VJPs. The nets here are tiny (h up to ~128), so these are plain
//! single-threaded loops ordered for row-contiguous access — the batched
//! MIPS hot path keeps using [`crate::tensor::gemm_nt`].

use crate::tensor::Tensor;

/// `A @ B` for `a [m,k]`, `b [k,n]` -> `[m,n]`.
pub fn matmul_nn(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.row_width());
    let (kb, n) = (b.rows(), b.row_width());
    assert_eq!(k, kb, "matmul_nn inner dim {k} vs {kb}");
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        let ai = a.row(i);
        let oi = out.row_mut(i);
        for (p, &av) in ai.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let bp = b.row(p);
            for (o, &bv) in oi.iter_mut().zip(bp) {
                *o += av * bv;
            }
        }
    }
    out
}

/// `A @ B^T` for `a [m,k]`, `b [n,k]` -> `[m,n]`.
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.row_width());
    let (n, kb) = (b.rows(), b.row_width());
    assert_eq!(k, kb, "matmul_nt inner dim {k} vs {kb}");
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        let ai = a.row(i);
        let oi = out.row_mut(i);
        for (j, o) in oi.iter_mut().enumerate() {
            *o = crate::tensor::dot(ai, b.row(j));
        }
    }
    out
}

/// `A^T @ B` for `a [m,k]`, `b [m,n]` -> `[k,n]`.
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.row_width());
    let (mb, n) = (b.rows(), b.row_width());
    assert_eq!(m, mb, "matmul_tn outer dim {m} vs {mb}");
    let mut out = Tensor::zeros(&[k, n]);
    for r in 0..m {
        let ar = a.row(r);
        let br = b.row(r);
        for (p, &av) in ar.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let op = out.row_mut(p);
            for (o, &bv) in op.iter_mut().zip(br) {
                *o += av * bv;
            }
        }
    }
    out
}

/// Column sums of `a [m,n]` -> `[n]` (bias gradients).
pub fn colsum(a: &Tensor) -> Tensor {
    let n = a.row_width();
    let mut out = Tensor::zeros(&[n]);
    for i in 0..a.rows() {
        for (o, &v) in out.data_mut().iter_mut().zip(a.row(i)) {
            *o += v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        t
    }

    fn naive(a: &Tensor, b: &Tensor, ta: bool, tb: bool) -> Vec<f32> {
        let (m, k) = if ta {
            (a.row_width(), a.rows())
        } else {
            (a.rows(), a.row_width())
        };
        let n = if tb { b.rows() } else { b.row_width() };
        let at = |i: usize, p: usize| {
            if ta {
                a.row(p)[i]
            } else {
                a.row(i)[p]
            }
        };
        let bt = |p: usize, j: usize| {
            if tb {
                b.row(j)[p]
            } else {
                b.row(p)[j]
            }
        };
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    out[i * n + j] += at(i, p) * bt(p, j);
                }
            }
        }
        out
    }

    #[test]
    fn variants_match_naive() {
        let a = randt(&[5, 7], 1);
        let b = randt(&[7, 4], 2);
        let c = randt(&[4, 7], 3);
        let d = randt(&[5, 3], 4);
        for (got, want) in [
            (matmul_nn(&a, &b), naive(&a, &b, false, false)),
            (matmul_nt(&a, &c), naive(&a, &c, false, true)),
            (matmul_tn(&a, &d), naive(&a, &d, true, false)),
        ] {
            assert_eq!(got.data().len(), want.len());
            for (g, w) in got.data().iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "{g} vs {w}");
            }
        }
    }

    #[test]
    fn colsum_matches_naive() {
        let a = randt(&[6, 3], 5);
        let s = colsum(&a);
        for j in 0..3 {
            let want: f32 = (0..6).map(|i| a.row(i)[j]).sum();
            assert!((s.data()[j] - want).abs() < 1e-5);
        }
    }
}
