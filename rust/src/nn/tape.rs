//! A minimal reverse-mode tape over dense tensors.
//!
//! Every VJP is written by hand (no operator-overloading magic): the tape
//! is just the bookkeeping that runs those hand-derived rules in reverse
//! creation order. Its one structural trick is that the *hand-derived
//! input-gradient* of a network (paper Sec. 3.1: the SupportNet key is
//! `∇_x f`) is itself built out of tape ops — `ActPrime` is a first-class
//! primitive whose own derivative is `σ''` — so the gradient-matching
//! loss `‖∇_x f − y*‖²` (Sec. 3.2) backpropagates to the weights through
//! one ordinary reverse pass over the extended graph. No second-order
//! machinery exists anywhere else.
//!
//! Nodes are append-only, so creation order is a topological order and
//! the backward pass is a single reverse sweep. Constants (queries,
//! targets) enter as leaves exactly like parameters; [`Tape::grad`]
//! prunes the sweep to the subgraph that can reach a requested leaf.

use crate::nn::activation::{act, act_prime, act_second};
use crate::nn::math::{colsum, matmul_nn, matmul_nt, matmul_tn};
use crate::tensor::Tensor;

/// Handle to one tape node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NodeId(usize);

#[derive(Clone, Copy)]
enum Op {
    /// Constant or parameter input.
    Leaf,
    /// `a @ b` — `a [m,k]`, `b [k,n]`.
    MatMul(NodeId, NodeId),
    /// `a @ b^T` — `a [m,k]`, `b [n,k]`.
    MatMulT(NodeId, NodeId),
    /// `a + b` with `b [n]` broadcast over the rows of `a [m,n]`.
    AddBias(NodeId, NodeId),
    Add(NodeId, NodeId),
    Sub(NodeId, NodeId),
    /// Elementwise product, same shape.
    Mul(NodeId, NodeId),
    /// Elementwise `σ(x)` with (alpha, beta).
    Act(NodeId, f32, f32),
    /// Elementwise `σ'(x)` — differentiable (its VJP uses `σ''`).
    ActPrime(NodeId, f32, f32),
    /// `out[i,:] = a[i,:] * v[i]` — `a [m,n]`, `v [m]`.
    ScaleRows(NodeId, NodeId),
    /// `out[i] = Σ_j a[i,j]·b[i,j]` — both `[m,n]`, out `[m]`.
    RowDot(NodeId, NodeId),
    /// `v [n]` repeated as every one of `m` rows.
    BcastRows(NodeId, usize),
    /// Columns `[start, start+len)` of `a [m,n]`.
    SliceCols(NodeId, usize, usize),
    Square(NodeId),
    /// Mean over every element -> scalar.
    MeanAll(NodeId),
    /// `Σ max(−x, 0)²` -> scalar (the loose ICNN convexity penalty).
    NegPartSq(NodeId),
    /// `c · a`.
    Scale(NodeId, f32),
}

struct Node {
    op: Op,
    value: Tensor,
}

/// Append-only computation tape.
#[derive(Default)]
pub struct Tape {
    nodes: Vec<Node>,
}

impl Tape {
    pub fn new() -> Tape {
        Tape::default()
    }

    fn push(&mut self, op: Op, value: Tensor) -> NodeId {
        self.nodes.push(Node { op, value });
        NodeId(self.nodes.len() - 1)
    }

    /// The computed value of a node.
    pub fn value(&self, id: NodeId) -> &Tensor {
        &self.nodes[id.0].value
    }

    /// Scalar value of a `[ ]`/len-1 node.
    pub fn scalar(&self, id: NodeId) -> f32 {
        debug_assert_eq!(self.nodes[id.0].value.len(), 1);
        self.nodes[id.0].value.data()[0]
    }

    // -- node constructors --------------------------------------------------

    pub fn leaf(&mut self, t: Tensor) -> NodeId {
        self.push(Op::Leaf, t)
    }

    pub fn matmul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let v = matmul_nn(self.value(a), self.value(b));
        self.push(Op::MatMul(a, b), v)
    }

    pub fn matmul_t(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let v = matmul_nt(self.value(a), self.value(b));
        self.push(Op::MatMulT(a, b), v)
    }

    pub fn add_bias(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let (av, bv) = (self.value(a), self.value(b));
        assert_eq!(av.row_width(), bv.len(), "add_bias width mismatch");
        let mut v = av.clone();
        let w = v.row_width();
        for row in v.data_mut().chunks_mut(w) {
            for (r, &b) in row.iter_mut().zip(bv.data()) {
                *r += b;
            }
        }
        self.push(Op::AddBias(a, b), v)
    }

    pub fn add(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let v = self.zip(a, b, |x, y| x + y);
        self.push(Op::Add(a, b), v)
    }

    pub fn sub(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let v = self.zip(a, b, |x, y| x - y);
        self.push(Op::Sub(a, b), v)
    }

    pub fn mul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let v = self.zip(a, b, |x, y| x * y);
        self.push(Op::Mul(a, b), v)
    }

    fn zip(&self, a: NodeId, b: NodeId, f: impl Fn(f32, f32) -> f32) -> Tensor {
        let (av, bv) = (self.value(a), self.value(b));
        assert_eq!(av.len(), bv.len(), "elementwise shape mismatch");
        let mut v = av.clone();
        for (x, &y) in v.data_mut().iter_mut().zip(bv.data()) {
            *x = f(*x, y);
        }
        v
    }

    pub fn act(&mut self, a: NodeId, alpha: f32, beta: f32) -> NodeId {
        let mut v = self.value(a).clone();
        for x in v.data_mut() {
            *x = act(*x, alpha, beta);
        }
        self.push(Op::Act(a, alpha, beta), v)
    }

    pub fn act_prime(&mut self, a: NodeId, alpha: f32, beta: f32) -> NodeId {
        let mut v = self.value(a).clone();
        for x in v.data_mut() {
            *x = act_prime(*x, alpha, beta);
        }
        self.push(Op::ActPrime(a, alpha, beta), v)
    }

    pub fn scale_rows(&mut self, a: NodeId, v: NodeId) -> NodeId {
        let (av, vv) = (self.value(a), self.value(v));
        assert_eq!(av.rows(), vv.len(), "scale_rows length mismatch");
        let mut out = av.clone();
        let w = out.row_width();
        for (i, row) in out.data_mut().chunks_mut(w).enumerate() {
            let s = vv.data()[i];
            for r in row {
                *r *= s;
            }
        }
        self.push(Op::ScaleRows(a, v), out)
    }

    pub fn row_dot(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let (av, bv) = (self.value(a), self.value(b));
        assert_eq!(av.shape(), bv.shape(), "row_dot shape mismatch");
        let m = av.rows();
        let mut out = Tensor::zeros(&[m]);
        for i in 0..m {
            out.data_mut()[i] = crate::tensor::dot(av.row(i), bv.row(i));
        }
        self.push(Op::RowDot(a, b), out)
    }

    pub fn bcast_rows(&mut self, v: NodeId, rows: usize) -> NodeId {
        let vv = self.value(v);
        let n = vv.len();
        let mut out = Tensor::zeros(&[rows, n]);
        for i in 0..rows {
            out.row_mut(i).copy_from_slice(vv.data());
        }
        self.push(Op::BcastRows(v, rows), out)
    }

    pub fn slice_cols(&mut self, a: NodeId, start: usize, len: usize) -> NodeId {
        let av = self.value(a);
        let (m, n) = (av.rows(), av.row_width());
        assert!(start + len <= n, "slice_cols out of range");
        let mut out = Tensor::zeros(&[m, len]);
        for i in 0..m {
            out.row_mut(i).copy_from_slice(&av.row(i)[start..start + len]);
        }
        self.push(Op::SliceCols(a, start, len), out)
    }

    pub fn square(&mut self, a: NodeId) -> NodeId {
        let mut v = self.value(a).clone();
        for x in v.data_mut() {
            *x *= *x;
        }
        self.push(Op::Square(a), v)
    }

    pub fn mean_all(&mut self, a: NodeId) -> NodeId {
        let av = self.value(a);
        let m = av.data().iter().sum::<f32>() / av.len().max(1) as f32;
        self.push(Op::MeanAll(a), Tensor::scalar(m))
    }

    pub fn neg_part_sq(&mut self, a: NodeId) -> NodeId {
        let s: f32 = self
            .value(a)
            .data()
            .iter()
            .map(|&x| if x < 0.0 { x * x } else { 0.0 })
            .sum();
        self.push(Op::NegPartSq(a), Tensor::scalar(s))
    }

    pub fn scale(&mut self, a: NodeId, c: f32) -> NodeId {
        let mut v = self.value(a).clone();
        for x in v.data_mut() {
            *x *= c;
        }
        self.push(Op::Scale(a, c), v)
    }

    // -- backward -----------------------------------------------------------

    /// Gradients of the scalar node `loss` with respect to each leaf in
    /// `wrt` (returned in the same order, zero tensors when a leaf does
    /// not influence the loss).
    pub fn grad(&self, loss: NodeId, wrt: &[NodeId]) -> Vec<Tensor> {
        assert_eq!(self.value(loss).len(), 1, "grad needs a scalar loss");
        // Forward reachability from the wanted leaves: node inputs always
        // have lower ids, so one forward sweep suffices.
        let mut reach = vec![false; self.nodes.len()];
        for id in wrt {
            reach[id.0] = true;
        }
        for i in 0..self.nodes.len() {
            if reach[i] {
                continue;
            }
            reach[i] = match self.nodes[i].op {
                Op::Leaf => false,
                Op::MatMul(a, b)
                | Op::MatMulT(a, b)
                | Op::AddBias(a, b)
                | Op::Add(a, b)
                | Op::Sub(a, b)
                | Op::Mul(a, b)
                | Op::ScaleRows(a, b)
                | Op::RowDot(a, b) => reach[a.0] || reach[b.0],
                Op::Act(a, _, _)
                | Op::ActPrime(a, _, _)
                | Op::BcastRows(a, _)
                | Op::SliceCols(a, _, _)
                | Op::Square(a)
                | Op::MeanAll(a)
                | Op::NegPartSq(a)
                | Op::Scale(a, _) => reach[a.0],
            };
        }

        let mut grads: Vec<Option<Tensor>> = (0..self.nodes.len()).map(|_| None).collect();
        grads[loss.0] = Some(Tensor::scalar(1.0));

        for i in (0..=loss.0).rev() {
            let Some(g) = grads[i].take() else { continue };
            if !reach[i] {
                continue;
            }
            match self.nodes[i].op {
                Op::Leaf => {
                    grads[i] = Some(g); // keep for collection below
                    continue;
                }
                Op::MatMul(a, b) => {
                    if reach[a.0] {
                        self.acc(&mut grads, a, matmul_nt(&g, self.value(b)));
                    }
                    if reach[b.0] {
                        self.acc(&mut grads, b, matmul_tn(self.value(a), &g));
                    }
                }
                Op::MatMulT(a, b) => {
                    if reach[a.0] {
                        self.acc(&mut grads, a, matmul_nn(&g, self.value(b)));
                    }
                    if reach[b.0] {
                        self.acc(&mut grads, b, matmul_tn(&g, self.value(a)));
                    }
                }
                Op::AddBias(a, b) => {
                    if reach[b.0] {
                        self.acc(&mut grads, b, colsum(&g));
                    }
                    if reach[a.0] {
                        self.acc(&mut grads, a, g);
                    }
                }
                Op::Add(a, b) => {
                    if reach[a.0] {
                        self.acc(&mut grads, a, g.clone());
                    }
                    if reach[b.0] {
                        self.acc(&mut grads, b, g);
                    }
                }
                Op::Sub(a, b) => {
                    if reach[b.0] {
                        let mut neg = g.clone();
                        for x in neg.data_mut() {
                            *x = -*x;
                        }
                        self.acc(&mut grads, b, neg);
                    }
                    if reach[a.0] {
                        self.acc(&mut grads, a, g);
                    }
                }
                Op::Mul(a, b) => {
                    if reach[a.0] {
                        self.acc(&mut grads, a, hadamard(&g, self.value(b)));
                    }
                    if reach[b.0] {
                        self.acc(&mut grads, b, hadamard(&g, self.value(a)));
                    }
                }
                Op::Act(a, alpha, beta) => {
                    if reach[a.0] {
                        let mut da = g;
                        for (x, &p) in da.data_mut().iter_mut().zip(self.value(a).data()) {
                            *x *= act_prime(p, alpha, beta);
                        }
                        self.acc(&mut grads, a, da);
                    }
                }
                Op::ActPrime(a, alpha, beta) => {
                    if reach[a.0] {
                        let mut da = g;
                        for (x, &p) in da.data_mut().iter_mut().zip(self.value(a).data()) {
                            *x *= act_second(p, alpha, beta);
                        }
                        self.acc(&mut grads, a, da);
                    }
                }
                Op::ScaleRows(a, v) => {
                    let (av, vv) = (self.value(a), self.value(v));
                    if reach[a.0] {
                        let mut da = g.clone();
                        let w = da.row_width();
                        for (r, row) in da.data_mut().chunks_mut(w).enumerate() {
                            let s = vv.data()[r];
                            for x in row {
                                *x *= s;
                            }
                        }
                        self.acc(&mut grads, a, da);
                    }
                    if reach[v.0] {
                        let mut dv = Tensor::zeros(&[vv.len()]);
                        for r in 0..av.rows() {
                            dv.data_mut()[r] = crate::tensor::dot(g.row(r), av.row(r));
                        }
                        self.acc(&mut grads, v, dv);
                    }
                }
                Op::RowDot(a, b) => {
                    let (av, bv) = (self.value(a), self.value(b));
                    if reach[a.0] {
                        self.acc(&mut grads, a, outer_rows(&g, bv));
                    }
                    if reach[b.0] {
                        self.acc(&mut grads, b, outer_rows(&g, av));
                    }
                }
                Op::BcastRows(v, _) => {
                    if reach[v.0] {
                        self.acc(&mut grads, v, colsum(&g));
                    }
                }
                Op::SliceCols(a, start, len) => {
                    if reach[a.0] {
                        let av = self.value(a);
                        let mut da = Tensor::zeros(&[av.rows(), av.row_width()]);
                        for r in 0..av.rows() {
                            da.row_mut(r)[start..start + len].copy_from_slice(g.row(r));
                        }
                        self.acc(&mut grads, a, da);
                    }
                }
                Op::Square(a) => {
                    if reach[a.0] {
                        let mut da = g;
                        for (x, &p) in da.data_mut().iter_mut().zip(self.value(a).data()) {
                            *x *= 2.0 * p;
                        }
                        self.acc(&mut grads, a, da);
                    }
                }
                Op::MeanAll(a) => {
                    if reach[a.0] {
                        let av = self.value(a);
                        let gs = g.data()[0] / av.len().max(1) as f32;
                        let mut da = Tensor::zeros(av.shape());
                        for x in da.data_mut() {
                            *x = gs;
                        }
                        self.acc(&mut grads, a, da);
                    }
                }
                Op::NegPartSq(a) => {
                    if reach[a.0] {
                        let gs = g.data()[0];
                        let mut da = self.value(a).clone();
                        for x in da.data_mut() {
                            *x = if *x < 0.0 { gs * 2.0 * *x } else { 0.0 };
                        }
                        self.acc(&mut grads, a, da);
                    }
                }
                Op::Scale(a, c) => {
                    if reach[a.0] {
                        let mut da = g;
                        for x in da.data_mut() {
                            *x *= c;
                        }
                        self.acc(&mut grads, a, da);
                    }
                }
            }
        }

        wrt.iter()
            .map(|id| {
                grads[id.0]
                    .take()
                    .unwrap_or_else(|| Tensor::zeros(self.value(*id).shape()))
            })
            .collect()
    }

    fn acc(&self, grads: &mut [Option<Tensor>], id: NodeId, delta: Tensor) {
        match &mut grads[id.0] {
            Some(g) => {
                debug_assert_eq!(g.len(), delta.len(), "gradient shape drift");
                for (x, &d) in g.data_mut().iter_mut().zip(delta.data()) {
                    *x += d;
                }
            }
            slot => *slot = Some(delta),
        }
    }
}

/// Elementwise product of equally-shaped tensors.
fn hadamard(a: &Tensor, b: &Tensor) -> Tensor {
    debug_assert_eq!(a.len(), b.len());
    let mut out = a.clone();
    for (x, &y) in out.data_mut().iter_mut().zip(b.data()) {
        *x *= y;
    }
    out
}

/// `out[i,j] = g[i] * m[i,j]` for `g [m]`, `m [m,n]`.
fn outer_rows(g: &Tensor, m: &Tensor) -> Tensor {
    let mut out = m.clone();
    let w = out.row_width();
    for (i, row) in out.data_mut().chunks_mut(w).enumerate() {
        let s = g.data()[i];
        for x in row {
            *x *= s;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        t
    }

    /// Scalar loss built from most ops; returns (loss, tape, leaf ids).
    fn build(w: &Tensor, b: &Tensor, x: &Tensor) -> (Tape, NodeId, NodeId, NodeId) {
        let mut t = Tape::new();
        let wi = t.leaf(w.clone());
        let bi = t.leaf(b.clone());
        let xi = t.leaf(x.clone());
        let pre0 = t.matmul(xi, wi);
        let pre = t.add_bias(pre0, bi);
        let z = t.act(pre, 0.1, 20.0);
        let zp = t.act_prime(pre, 0.1, 20.0);
        let m = t.mul(z, zp);
        let rd = t.row_dot(m, xi); // needs widths to match: h == d in tests
        let mt = t.matmul_t(m, wi); // [B,h] @ w^T(as [d,h]) -> [B,d]
        let sr = t.scale_rows(mt, rd);
        let sc = t.slice_cols(sr, 0, x.row_width());
        let sq = t.square(sc);
        let mean = t.mean_all(sq);
        let pen = t.neg_part_sq(wi);
        let pen_s = t.scale(pen, 0.05);
        let loss = t.add(mean, pen_s);
        (t, loss, wi, bi)
    }

    fn loss_value(w: &Tensor, b: &Tensor, x: &Tensor) -> f32 {
        let (t, loss, _, _) = build(w, b, x);
        t.scalar(loss)
    }

    #[test]
    fn composite_graph_matches_finite_differences() {
        // d == h so row_dot/matmul_t shapes line up
        let w = randt(&[4, 4], 1);
        let b = randt(&[4], 2);
        let x = randt(&[3, 4], 3);
        let (t, loss, wi, bi) = build(&w, &b, &x);
        let grads = t.grad(loss, &[wi, bi]);
        let eps = 1e-2f32;
        for (leaf, base) in [(0usize, &w), (1usize, &b)] {
            let g = &grads[leaf];
            for e in 0..base.len() {
                let mut hi = base.clone();
                hi.data_mut()[e] += eps;
                let mut lo = base.clone();
                lo.data_mut()[e] -= eps;
                let (fh, fl) = if leaf == 0 {
                    (loss_value(&hi, &b, &x), loss_value(&lo, &b, &x))
                } else {
                    (loss_value(&w, &hi, &x), loss_value(&w, &lo, &x))
                };
                let fd = (fh - fl) / (2.0 * eps);
                let an = g.data()[e];
                assert!(
                    (fd - an).abs() < 2e-3 + 0.03 * fd.abs().max(an.abs()),
                    "leaf {leaf} elem {e}: fd {fd} vs backprop {an}"
                );
            }
        }
    }

    #[test]
    fn unreached_leaf_gets_zero_gradient() {
        let mut t = Tape::new();
        let a = t.leaf(randt(&[2, 2], 4));
        let unused = t.leaf(randt(&[2, 2], 5));
        let sq = t.square(a);
        let loss = t.mean_all(sq);
        let g = t.grad(loss, &[unused]);
        assert!(g[0].data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn fan_out_accumulates() {
        // loss = mean(a ⊙ a) uses `a` twice through Mul
        let av = randt(&[2, 3], 6);
        let mut t = Tape::new();
        let a = t.leaf(av.clone());
        let m = t.mul(a, a);
        let loss = t.mean_all(m);
        let g = t.grad(loss, &[a]);
        for (ge, &ae) in g[0].data().iter().zip(av.data()) {
            let want = 2.0 * ae / 6.0;
            assert!((ge - want).abs() < 1e-5, "{ge} vs {want}");
        }
    }

    #[test]
    fn bcast_rows_sums_back() {
        let mut t = Tape::new();
        let v = t.leaf(randt(&[3], 7));
        let b = t.bcast_rows(v, 5);
        let loss = t.mean_all(b);
        let g = t.grad(loss, &[v]);
        for ge in g[0].data() {
            assert!((ge - 5.0 / 15.0).abs() < 1e-6);
        }
    }
}
