//! The paper's activation (Sec. 3.3) and its first two derivatives:
//!
//! ```text
//! sigma_{alpha,beta}(x) = alpha*x + (1-alpha)/beta * softplus(beta*x)
//! ```
//!
//! As `beta -> inf` this approaches leaky-ReLU with negative slope
//! `alpha`; it is smooth everywhere, which the SupportNet training loss
//! needs: the gradient-matching term differentiates *through* the
//! input-gradient, so the second derivative must exist (and is exported
//! here for the tape's `ActPrime` VJP).

/// Numerically stable softplus: `log1p(exp(t)) = max(t,0) + log1p(exp(-|t|))`.
#[inline]
fn softplus(t: f32) -> f32 {
    t.max(0.0) + (-t.abs()).exp().ln_1p()
}

/// Numerically stable logistic sigmoid.
#[inline]
fn sigmoid(t: f32) -> f32 {
    if t >= 0.0 {
        1.0 / (1.0 + (-t).exp())
    } else {
        let e = t.exp();
        e / (1.0 + e)
    }
}

/// `sigma(x)`.
#[inline]
pub fn act(x: f32, alpha: f32, beta: f32) -> f32 {
    alpha * x + (1.0 - alpha) / beta * softplus(beta * x)
}

/// `sigma'(x) = alpha + (1-alpha) * sigmoid(beta*x)`.
#[inline]
pub fn act_prime(x: f32, alpha: f32, beta: f32) -> f32 {
    alpha + (1.0 - alpha) * sigmoid(beta * x)
}

/// `sigma''(x) = (1-alpha) * beta * s(1-s)` with `s = sigmoid(beta*x)`.
#[inline]
pub fn act_second(x: f32, alpha: f32, beta: f32) -> f32 {
    let s = sigmoid(beta * x);
    (1.0 - alpha) * beta * s * (1.0 - s)
}

#[cfg(test)]
mod tests {
    use super::*;

    const A: f32 = 0.1;
    const B: f32 = 20.0;

    #[test]
    fn limits_match_leaky_relu() {
        // far from zero the smooth unit coincides with leaky-ReLU
        assert!((act(3.0, A, B) - 3.0).abs() < 1e-4);
        assert!((act(-3.0, A, B) - (-0.3)).abs() < 1e-4);
        assert!((act_prime(3.0, A, B) - 1.0).abs() < 1e-4);
        assert!((act_prime(-3.0, A, B) - A).abs() < 1e-4);
    }

    #[test]
    fn derivatives_match_finite_differences() {
        let eps = 1e-3f32;
        for &x in &[-2.0f32, -0.3, -0.01, 0.0, 0.02, 0.5, 1.7] {
            let fd1 = (act(x + eps, A, B) - act(x - eps, A, B)) / (2.0 * eps);
            assert!(
                (fd1 - act_prime(x, A, B)).abs() < 1e-3,
                "sigma' at {x}: fd {fd1} vs {}",
                act_prime(x, A, B)
            );
            let fd2 = (act_prime(x + eps, A, B) - act_prime(x - eps, A, B)) / (2.0 * eps);
            assert!(
                (fd2 - act_second(x, A, B)).abs() < 2e-2 * (1.0 + fd2.abs()),
                "sigma'' at {x}: fd {fd2} vs {}",
                act_second(x, A, B)
            );
        }
    }

    #[test]
    fn no_overflow_at_extremes() {
        for &x in &[-1e4f32, 1e4] {
            assert!(act(x, A, B).is_finite());
            assert!(act_prime(x, A, B).is_finite());
            assert!(act_second(x, A, B).is_finite());
        }
    }
}
