//! Pure-Rust neural-network layer for the paper's learned models — the
//! backend that makes SupportNet/KeyNet training and serving work in the
//! default build, with no XLA, Python or network access.
//!
//! * [`spec`] — [`NetSpec`]: the rectangular trunk architecture
//!   (Sec. 3.1), the paper's width-for-budget sizing rule (Eq. 3.3) and
//!   the ordered parameter ABI shared with checkpoints/artifacts.
//! * [`activation`] — the smooth leaky unit `σ_{α,β}` and its first two
//!   derivatives (the second is what lets the gradient-matching loss
//!   backpropagate through the input gradient).
//! * [`tape`] — a minimal reverse-mode tape with hand-written VJPs;
//!   append-only, so one reverse sweep differentiates any graph built on
//!   it, including the hand-derived input-gradient recurrence.
//! * [`net`] — [`Network`]: SupportNet (homogenized loosely-constrained
//!   ICNN, keys via the input gradient) and KeyNet (direct key
//!   regression with the Euler score-consistency loss) on one trunk.
//!
//! The training loop that drives this lives in [`crate::trainer::rust`];
//! the serving-side handle is [`crate::model::RustModel`].

pub mod activation;
pub mod math;
pub mod net;
pub mod spec;
pub mod tape;

pub use net::{Lambdas, LossParts, Network};
pub use spec::{inject_layers, width_for_budget, ModelKind, NetSpec};
pub use tape::{NodeId, Tape};
