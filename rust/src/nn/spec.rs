//! Static architecture description for the pure-Rust SupportNet/KeyNet
//! stack, mirroring `python/compile/model.py::Arch` and the sizing rule
//! in `python/compile/sizing.py` (paper Eq. 3.2/3.3): both models share
//! one rectangular trunk
//!
//! ```text
//! z_1     = σ(Wx0 x + b0)
//! z_{i+1} = σ(Wz_i z_i [+ Wx_i x] + b_i)      (+ z_i if residual)
//! out     = W_L z_L + b_L
//! ```
//!
//! SupportNet heads are scalar support values (convexity encouraged by a
//! non-negativity *penalty* on the `Wz_i`, "loosely constrained" ICNN)
//! and are wrapped by the homogenization `H[g](x) = ‖x‖·g(x/‖x‖)`
//! (Eq. 3.4); KeyNet heads regress the `c·d` key coordinates directly.

use anyhow::{ensure, Result};

use crate::tensor::Tensor;
use crate::util::Rng;

/// Which of the paper's two amortized models a network implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    /// Scalar support-function model; keys recovered via the input
    /// gradient (paper Sec. 3.1 approach 1).
    SupportNet,
    /// Direct key regression (approach 2).
    KeyNet,
}

impl ModelKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ModelKind::SupportNet => "supportnet",
            ModelKind::KeyNet => "keynet",
        }
    }

    pub fn parse(s: &str) -> Result<ModelKind> {
        match s {
            "supportnet" => Ok(ModelKind::SupportNet),
            "keynet" => Ok(ModelKind::KeyNet),
            other => anyhow::bail!("unknown model kind '{other}' (supportnet|keynet)"),
        }
    }
}

impl std::fmt::Display for ModelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Hidden-layer indices (1..layers-1) that receive the x re-injection;
/// `nx` counts injections after the first layer, evenly spaced (mirrors
/// `sizing.inject_layers`).
pub fn inject_layers(layers: usize, nx: usize) -> Vec<usize> {
    if layers <= 1 || nx == 0 {
        return Vec::new();
    }
    let nx = nx.min(layers - 1);
    let step = (layers - 1) as f64 / nx as f64;
    let mut out: Vec<usize> = (0..nx)
        .map(|i| (((i + 1) as f64 * step).round() as usize).clamp(1, layers - 1))
        .collect();
    out.sort_unstable();
    out.dedup();
    out
}

/// Hidden width for a parameter budget `p ≈ rho·n·d` (paper Eq. 3.3),
/// rounded to a multiple of 8 (>= 8).
pub fn width_for_budget(p: f64, layers: usize, d: usize, nx: usize) -> usize {
    let dd = ((1 + nx.min(layers.saturating_sub(1))) * d) as f64;
    let h = if layers <= 1 {
        p / dd.max(1.0)
    } else {
        let l1 = (layers - 1) as f64;
        ((dd * dd + 4.0 * l1 * p).sqrt() - dd) / (2.0 * l1)
    };
    (((h / 8.0).round() as usize) * 8).max(8)
}

/// Architecture of one SupportNet/KeyNet instance.
#[derive(Clone, Debug, PartialEq)]
pub struct NetSpec {
    pub model: ModelKind,
    /// Embedding dimension.
    pub d: usize,
    /// Output heads (clusters routed over; 1 for the mapped query path).
    pub c: usize,
    /// Hidden width.
    pub h: usize,
    /// Hidden layers, including the input layer.
    pub layers: usize,
    /// Input re-injections after the first layer.
    pub nx: usize,
    pub residual: bool,
    /// Positive-1-homogeneity wrapper (SupportNet only; forced off for
    /// KeyNet by [`NetSpec::new`]).
    pub homogenize: bool,
    /// Activation knobs (soft leaky ReLU).
    pub alpha: f32,
    pub beta: f32,
}

impl NetSpec {
    /// Paper-default spec: homogenization on for SupportNet, off for
    /// KeyNet; `nx = layers` (inject everywhere), `alpha/beta` defaults.
    pub fn new(model: ModelKind, d: usize, c: usize, h: usize, layers: usize) -> NetSpec {
        NetSpec {
            model,
            d,
            c,
            h,
            layers,
            nx: layers,
            residual: false,
            homogenize: model == ModelKind::SupportNet,
            alpha: 0.1,
            beta: 20.0,
        }
    }

    /// Spec sized from the paper's budget rule: `h` solves
    /// `(L-1)h² + (1+nx)dh ≈ rho·n·d` for a database of `n` keys.
    pub fn sized(model: ModelKind, d: usize, c: usize, n_keys: usize, rho: f64, layers: usize) -> NetSpec {
        let h = width_for_budget(rho * n_keys as f64 * d as f64, layers, d, layers);
        NetSpec::new(model, d, c, h, layers)
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.d >= 1 && self.d <= 1 << 16, "d={} out of range", self.d);
        ensure!(self.c >= 1 && self.c <= 1 << 12, "c={} out of range", self.c);
        ensure!(self.h >= 1 && self.h <= 1 << 14, "h={} out of range", self.h);
        ensure!(
            self.layers >= 1 && self.layers <= 64,
            "layers={} out of range",
            self.layers
        );
        ensure!(self.nx <= 64, "nx={} out of range", self.nx);
        ensure!(
            self.alpha.is_finite() && self.beta.is_finite() && self.beta > 0.0,
            "bad activation knobs alpha={} beta={}",
            self.alpha,
            self.beta
        );
        ensure!(
            !(self.homogenize && self.model == ModelKind::KeyNet),
            "homogenization applies to SupportNet only"
        );
        Ok(())
    }

    /// Head width: `c` support values or `c·d` key coordinates.
    pub fn d_out(&self) -> usize {
        match self.model {
            ModelKind::SupportNet => self.c,
            ModelKind::KeyNet => self.c * self.d,
        }
    }

    pub fn inject(&self) -> Vec<usize> {
        inject_layers(self.layers, self.nx)
    }

    /// Ordered `(name, shape)` parameter list — the checkpoint/artifact
    /// ABI, same naming scheme as the Python export.
    pub fn param_specs(&self) -> Vec<(String, Vec<usize>)> {
        let (d, h) = (self.d, self.h);
        let mut specs = vec![("wx0".to_string(), vec![d, h]), ("b0".to_string(), vec![h])];
        let inj = self.inject();
        for i in 1..self.layers {
            specs.push((format!("wz{i}"), vec![h, h]));
            if inj.contains(&i) {
                specs.push((format!("wx{i}"), vec![d, h]));
            }
            specs.push((format!("b{i}"), vec![h]));
        }
        specs.push(("wout".to_string(), vec![h, self.d_out()]));
        specs.push(("bout".to_string(), vec![self.d_out()]));
        specs
    }

    /// Indices (into [`NetSpec::param_specs`]) of the matrices under the
    /// ICNN non-negativity penalty: every `Wz_i`, plus the output head
    /// for SupportNet (convexity of `W_L z_L + b_L` needs `W_L >= 0`).
    pub fn icnn_penalty_indices(&self) -> Vec<usize> {
        let specs = self.param_specs();
        let mut idx: Vec<usize> = specs
            .iter()
            .enumerate()
            .filter(|(_, (n, _))| n.starts_with("wz"))
            .map(|(i, _)| i)
            .collect();
        if self.model == ModelKind::SupportNet {
            if let Some(i) = specs.iter().position(|(n, _)| n == "wout") {
                idx.push(i);
            }
        }
        idx
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        self.param_specs()
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }

    /// FLOPs for one query forward pass (multiply+add = 2, mirrors
    /// `sizing.forward_flops` so pure-Rust and XLA cost axes agree).
    pub fn forward_flops(&self) -> u64 {
        let (d, h, l) = (self.d as u64, self.h as u64, self.layers as u64);
        let d_out = self.d_out() as u64;
        let n_inj = self.inject().len() as u64;
        let mut f = 2 * d * h;
        f += (l - 1) * 2 * h * h;
        f += n_inj * 2 * d * h;
        f += 2 * h * d_out;
        f += 8 * (h * l + d_out);
        if self.homogenize {
            f += 6 * d;
        }
        f
    }

    /// FLOPs for recovering keys for one query: KeyNet reads them from
    /// the forward pass; SupportNet pays the forward plus `c` backward
    /// passes (~2x forward each, paper Sec. 4.4).
    pub fn key_flops(&self) -> u64 {
        match self.model {
            ModelKind::KeyNet => self.forward_flops(),
            ModelKind::SupportNet => self.forward_flops() * (1 + 2 * self.c as u64),
        }
    }

    /// Initial parameters (mirrors `model.init_params`): zero biases,
    /// LeCun-normal passthroughs/head, and for SupportNet a scaled
    /// half-normal on the penalty targets so `Wz >= 0` at init.
    pub fn init_params(&self, seed: u64) -> Vec<Tensor> {
        let specs = self.param_specs();
        let wz: std::collections::BTreeSet<usize> =
            self.icnn_penalty_indices().into_iter().collect();
        let mut rng = Rng::new(seed ^ 0x11CC);
        specs
            .iter()
            .enumerate()
            .map(|(i, (_, shape))| {
                let mut t = Tensor::zeros(shape);
                if shape.len() >= 2 {
                    let fan_in = shape[0] as f32;
                    if wz.contains(&i) && self.model == ModelKind::SupportNet {
                        let std = (2.0 / fan_in).sqrt() * 0.5;
                        for v in t.data_mut() {
                            *v = (rng.normal().abs() as f32) * std;
                        }
                    } else {
                        let std = (1.0 / fan_in).sqrt();
                        rng.fill_normal(t.data_mut(), std);
                    }
                }
                t
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inject_layers_mirror_python_rule() {
        assert!(inject_layers(1, 4).is_empty());
        assert!(inject_layers(4, 0).is_empty());
        // nx >= L-1 injects every hidden layer
        assert_eq!(inject_layers(4, 4), vec![1, 2, 3]);
        assert_eq!(inject_layers(4, 3), vec![1, 2, 3]);
        // one injection lands on the last hidden layer
        assert_eq!(inject_layers(4, 1), vec![3]);
    }

    #[test]
    fn width_solves_budget() {
        // h must approximately satisfy (L-1)h^2 + (1+nx)dh = P
        let (l, d, nx) = (4usize, 64usize, 4usize);
        let p = 0.05 * 16384.0 * 64.0;
        let h = width_for_budget(p, l, d, nx) as f64;
        let achieved = (l - 1) as f64 * h * h + (1 + nx.min(l - 1)) as f64 * d as f64 * h;
        assert!((achieved - p).abs() / p < 0.25, "h={h} achieved={achieved}");
        assert_eq!(width_for_budget(10.0, 2, 8, 1) % 8, 0);
        assert!(width_for_budget(0.0, 2, 8, 1) >= 8);
    }

    #[test]
    fn param_specs_count_and_order() {
        let spec = NetSpec::new(ModelKind::KeyNet, 8, 2, 16, 3);
        let specs = spec.param_specs();
        assert_eq!(specs[0].0, "wx0");
        assert_eq!(specs[0].1, vec![8, 16]);
        assert_eq!(specs.last().unwrap().0, "bout");
        assert_eq!(specs.last().unwrap().1, vec![16]); // c*d = 16
        let n: usize = specs.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        assert_eq!(n, spec.n_params());
        // wz penalty excludes the head for keynet, includes it for supportnet
        assert!(spec
            .icnn_penalty_indices()
            .iter()
            .all(|&i| specs[i].0.starts_with("wz")));
        let sn = NetSpec::new(ModelKind::SupportNet, 8, 2, 16, 3);
        let sn_specs = sn.param_specs();
        assert!(sn
            .icnn_penalty_indices()
            .iter()
            .any(|&i| sn_specs[i].0 == "wout"));
    }

    #[test]
    fn init_shapes_match_and_supportnet_wz_nonnegative() {
        let spec = NetSpec::new(ModelKind::SupportNet, 6, 1, 8, 3);
        let params = spec.init_params(7);
        let specs = spec.param_specs();
        assert_eq!(params.len(), specs.len());
        for (p, (_, s)) in params.iter().zip(&specs) {
            assert_eq!(p.shape(), &s[..]);
        }
        for &i in &spec.icnn_penalty_indices() {
            assert!(params[i].data().iter().all(|&v| v >= 0.0), "{}", specs[i].0);
        }
        // biases start at zero
        assert!(params[1].data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn keynet_never_homogenizes() {
        let spec = NetSpec::new(ModelKind::KeyNet, 4, 1, 8, 2);
        assert!(!spec.homogenize);
        let mut bad = spec;
        bad.homogenize = true;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn flops_scale_with_width() {
        let small = NetSpec::new(ModelKind::KeyNet, 16, 1, 16, 3);
        let big = NetSpec::new(ModelKind::KeyNet, 16, 1, 64, 3);
        assert!(big.forward_flops() > small.forward_flops());
        let sn = NetSpec::new(ModelKind::SupportNet, 16, 4, 16, 3);
        assert!(sn.key_flops() > sn.forward_flops());
    }
}
