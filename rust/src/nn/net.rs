//! The SupportNet/KeyNet network: forward inference, hand-derived input
//! gradients (SupportNet key recovery), and the paper's training losses
//! with gradients for every parameter.
//!
//! One set of graph builders serves both inference and training — the
//! forward trunk and the input-gradient recurrence are built on the
//! [`Tape`] either way, so the quantity the trainer matches against
//! `y*` is bit-identical to the quantity served at inference time.
//!
//! The input gradient is *not* produced by differentiating code: it is
//! the closed-form reverse recurrence of the trunk,
//!
//! ```text
//! a_L = Wout[:, j],   s_i = a_i ⊙ σ'(pre_i),
//! a_{i-1} = Wz_i^T s_i (+ a_i if residual),
//! ∇_x g_j = Wx0 s_1 + Σ_{i ∈ inject} Wx_i s_i,
//! ```
//!
//! expressed in tape ops so the gradient-matching loss (Sec. 3.2) can
//! differentiate through it. With the homogenization wrapper
//! `f(x) = ‖x‖ g(x/‖x‖)` the served key becomes
//! `∇f(x) = g(u)·u + (I − u u^T)∇g(u)` with `u = x/‖x‖`, which satisfies
//! Euler's identity `⟨∇f(x), x⟩ = f(x)` exactly (asserted by the
//! property tests).

use anyhow::{ensure, Result};

use crate::nn::spec::{ModelKind, NetSpec};
use crate::nn::tape::{NodeId, Tape};
use crate::tensor::Tensor;

/// Loss weights, named after the uniform (lam_a, lam_b) convention the
/// train step uses (see [`crate::trainer::TrainOpts`]):
/// SupportNet: `lam_a`=score, `lam_b`=gradient-matching;
/// KeyNet: `lam_a`=consistency, `lam_b`=key regression.
/// `lam_icnn` weights the SupportNet convexity penalty.
#[derive(Clone, Copy, Debug)]
pub struct Lambdas {
    pub lam_a: f32,
    pub lam_b: f32,
    pub lam_icnn: f32,
}

/// Scalar loss terms of one batch.
#[derive(Clone, Copy, Debug, Default)]
pub struct LossParts {
    pub total: f32,
    /// SupportNet: score loss; KeyNet: key-regression loss.
    pub loss_a: f32,
    /// SupportNet: gradient-matching loss; KeyNet: consistency loss.
    pub loss_b: f32,
    /// ICNN non-negativity penalty (0 for KeyNet).
    pub penalty: f32,
}

/// A network instance: spec + parameters in [`NetSpec::param_specs`]
/// order.
#[derive(Clone, Debug)]
pub struct Network {
    spec: NetSpec,
    params: Vec<Tensor>,
    /// Parameter names in spec order, resolved once — name lookups
    /// during graph building must not re-derive (and re-allocate) the
    /// spec's param list per call.
    names: Vec<String>,
}

/// Per-batch graph handles shared by inference and training builders.
struct Trunk {
    /// Pre-activation of every hidden layer, in order.
    pres: Vec<NodeId>,
    /// Head output `[B, d_out]` (raw, before homogenization).
    out: NodeId,
}

impl Network {
    /// Wrap explicit parameters, validating shapes against the spec.
    pub fn new(spec: NetSpec, params: Vec<Tensor>) -> Result<Network> {
        spec.validate()?;
        let specs = spec.param_specs();
        ensure!(
            params.len() == specs.len(),
            "{} params supplied, spec wants {}",
            params.len(),
            specs.len()
        );
        for (p, (name, shape)) in params.iter().zip(&specs) {
            ensure!(
                p.shape() == &shape[..],
                "param {name} has shape {:?}, spec wants {:?}",
                p.shape(),
                shape
            );
        }
        let names = specs.into_iter().map(|(n, _)| n).collect();
        Ok(Network {
            spec,
            params,
            names,
        })
    }

    /// Fresh network with the paper's initialization.
    pub fn init(spec: NetSpec, seed: u64) -> Result<Network> {
        let params = spec.init_params(seed);
        Network::new(spec, params)
    }

    pub fn spec(&self) -> &NetSpec {
        &self.spec
    }

    pub fn params(&self) -> &[Tensor] {
        &self.params
    }

    /// In-place access for the optimizer's parameter updates (element
    /// values only — shapes were validated at construction and tensors
    /// must not be replaced wholesale).
    pub fn params_mut(&mut self) -> &mut [Tensor] {
        &mut self.params
    }

    /// Replace the parameter tensors (trainer EMA snapshots).
    pub fn set_params(&mut self, params: Vec<Tensor>) -> Result<()> {
        *self = Network::new(self.spec.clone(), params)?;
        Ok(())
    }

    fn param_index(&self, name: &str) -> usize {
        self.names
            .iter()
            .position(|n| n == name)
            .unwrap_or_else(|| panic!("no param named {name}"))
    }

    /// Push every parameter onto the tape, returning ids in spec order.
    fn param_leaves(&self, tape: &mut Tape) -> Vec<NodeId> {
        self.params.iter().map(|p| tape.leaf(p.clone())).collect()
    }

    /// Build the shared trunk + head at `input` (`[B, d]` node).
    fn build_trunk(&self, tape: &mut Tape, pids: &[NodeId], input: NodeId) -> Trunk {
        let spec = &self.spec;
        let (alpha, beta) = (spec.alpha, spec.beta);
        let inject = spec.inject();
        let pid = |name: &str| pids[self.param_index(name)];

        let mut pres = Vec::with_capacity(spec.layers);
        let xw = tape.matmul(input, pid("wx0"));
        let pre0 = tape.add_bias(xw, pid("b0"));
        pres.push(pre0);
        let mut z = tape.act(pre0, alpha, beta);
        for li in 1..spec.layers {
            let mut pre = tape.matmul(z, pid(&format!("wz{li}")));
            if inject.contains(&li) {
                let xi = tape.matmul(input, pid(&format!("wx{li}")));
                pre = tape.add(pre, xi);
            }
            let pre = tape.add_bias(pre, pid(&format!("b{li}")));
            pres.push(pre);
            let a = tape.act(pre, alpha, beta);
            z = if spec.residual { tape.add(z, a) } else { a };
        }
        let zo = tape.matmul(z, pid("wout"));
        let out = tape.add_bias(zo, pid("bout"));
        Trunk { pres, out }
    }

    /// Hand-derived input gradient `∇_input g_head` of the raw trunk,
    /// built from tape ops (so it is itself differentiable): `[B, d]`.
    fn build_input_grad(
        &self,
        tape: &mut Tape,
        pids: &[NodeId],
        trunk: &Trunk,
        head: usize,
        batch: usize,
    ) -> NodeId {
        let spec = &self.spec;
        let (alpha, beta) = (spec.alpha, spec.beta);
        let inject = spec.inject();
        let pid = |name: &str| pids[self.param_index(name)];

        let wcol = tape.slice_cols(pid("wout"), head, 1); // [h, 1]
        let mut a = tape.bcast_rows(wcol, batch); // [B, h]
        let mut gx: Option<NodeId> = None;
        let add_gx = |tape: &mut Tape, gx: &mut Option<NodeId>, c: NodeId| {
            *gx = Some(match *gx {
                Some(acc) => tape.add(acc, c),
                None => c,
            });
        };
        for li in (1..spec.layers).rev() {
            let sp = tape.act_prime(trunk.pres[li], alpha, beta);
            let s = tape.mul(a, sp);
            if inject.contains(&li) {
                let c = tape.matmul_t(s, pid(&format!("wx{li}")));
                add_gx(tape, &mut gx, c);
            }
            let back = tape.matmul_t(s, pid(&format!("wz{li}")));
            a = if spec.residual { tape.add(back, a) } else { back };
        }
        let sp0 = tape.act_prime(trunk.pres[0], alpha, beta);
        let s0 = tape.mul(a, sp0);
        let c0 = tape.matmul_t(s0, pid("wx0"));
        add_gx(tape, &mut gx, c0);
        gx.expect("at least the wx0 path contributes")
    }

    /// Row norms (clamped away from zero) and unit-normalized copy.
    fn normalize(x: &Tensor) -> (Tensor, Tensor) {
        let (n, d) = (x.rows(), x.row_width());
        let mut r = Tensor::zeros(&[n]);
        let mut u = x.clone();
        for i in 0..n {
            let nrm = crate::tensor::dot(x.row(i), x.row(i)).sqrt().max(1e-12);
            r.data_mut()[i] = nrm;
            for v in u.row_mut(i) {
                *v /= nrm;
            }
        }
        debug_assert_eq!(u.row_width(), d);
        (r, u)
    }

    fn check_queries(&self, x: &Tensor) -> Result<()> {
        ensure!(
            x.row_width() == self.spec.d,
            "query dim {} != model dim {}",
            x.row_width(),
            self.spec.d
        );
        ensure!(x.rows() > 0, "empty query batch");
        Ok(())
    }

    /// SupportNet graph: (scores node `[B,c]`, per-head key nodes
    /// `[B,d]`). `with_keys=false` skips the input-gradient graphs.
    fn build_supportnet(
        &self,
        tape: &mut Tape,
        pids: &[NodeId],
        x: &Tensor,
        with_keys: bool,
    ) -> (NodeId, Vec<NodeId>) {
        let spec = &self.spec;
        let batch = x.rows();
        let (scores, keys) = if spec.homogenize {
            let (r, u) = Self::normalize(x);
            let u_leaf = tape.leaf(u);
            let r_leaf = tape.leaf(r);
            let trunk = self.build_trunk(tape, pids, u_leaf);
            let scores = tape.scale_rows(trunk.out, r_leaf);
            let mut keys = Vec::new();
            if with_keys {
                for j in 0..spec.c {
                    let gx = self.build_input_grad(tape, pids, &trunk, j, batch);
                    // ∇f = g(u)·u + (I − u uᵀ)∇g(u)
                    let gj = tape.slice_cols(trunk.out, j, 1); // [B,1]
                    let term1 = tape.scale_rows(u_leaf, gj);
                    let radial = tape.row_dot(gx, u_leaf); // [B]
                    let term3 = tape.scale_rows(u_leaf, radial);
                    let sum = tape.add(term1, gx);
                    keys.push(tape.sub(sum, term3));
                }
            }
            (scores, keys)
        } else {
            let x_leaf = tape.leaf(x.clone());
            let trunk = self.build_trunk(tape, pids, x_leaf);
            let mut keys = Vec::new();
            if with_keys {
                for j in 0..spec.c {
                    keys.push(self.build_input_grad(tape, pids, &trunk, j, batch));
                }
            }
            (trunk.out, keys)
        };
        (scores, keys)
    }

    /// Per-cluster support scores `[n, c]`.
    ///
    /// SupportNet reads them from the (homogenized) forward pass; KeyNet
    /// derives them as `⟨F_j(x), x⟩` (Euler consistency).
    pub fn scores(&self, x: &Tensor) -> Result<Tensor> {
        self.check_queries(x)?;
        match self.spec.model {
            ModelKind::SupportNet => {
                let mut tape = Tape::new();
                let pids = self.param_leaves(&mut tape);
                let (scores, _) = self.build_supportnet(&mut tape, &pids, x, false);
                Ok(tape.value(scores).clone())
            }
            ModelKind::KeyNet => Ok(self.scores_and_keys(x)?.0),
        }
    }

    /// Scores **and** predicted keys: `([n,c], [n,c,d])`.
    ///
    /// SupportNet pays the per-head backward recurrence here (the
    /// paper's Table-1 asymmetry); KeyNet gets keys from the same
    /// forward pass.
    pub fn scores_and_keys(&self, x: &Tensor) -> Result<(Tensor, Tensor)> {
        self.check_queries(x)?;
        let (n, d, c) = (x.rows(), self.spec.d, self.spec.c);
        match self.spec.model {
            ModelKind::SupportNet => {
                let mut tape = Tape::new();
                let pids = self.param_leaves(&mut tape);
                let (scores, key_nodes) = self.build_supportnet(&mut tape, &pids, x, true);
                let mut keys = Tensor::zeros(&[n, c, d]);
                for (j, kn) in key_nodes.iter().enumerate() {
                    let kv = tape.value(*kn);
                    for b in 0..n {
                        let off = (b * c + j) * d;
                        keys.data_mut()[off..off + d].copy_from_slice(kv.row(b));
                    }
                }
                Ok((tape.value(scores).clone(), keys))
            }
            ModelKind::KeyNet => {
                let mut tape = Tape::new();
                let pids = self.param_leaves(&mut tape);
                let x_leaf = tape.leaf(x.clone());
                let trunk = self.build_trunk(&mut tape, &pids, x_leaf);
                let out = tape.value(trunk.out).clone(); // [n, c*d]
                let mut scores = Tensor::zeros(&[n, c]);
                for b in 0..n {
                    let row = out.row(b);
                    for j in 0..c {
                        scores.row_mut(b)[j] =
                            crate::tensor::dot(&row[j * d..(j + 1) * d], x.row(b));
                    }
                }
                Ok((scores, out.reshape(&[n, c, d])))
            }
        }
    }

    /// Training losses and parameter gradients for one batch:
    /// `x [B,d]`, `y_star [B,c,d]`, `sigma [B,c]`.
    pub fn loss_and_grads(
        &self,
        x: &Tensor,
        y_star: &Tensor,
        sigma: &Tensor,
        lam: &Lambdas,
    ) -> Result<(LossParts, Vec<Tensor>)> {
        self.check_queries(x)?;
        let (b, c, d) = (x.rows(), self.spec.c, self.spec.d);
        ensure!(
            y_star.shape() == &[b, c, d][..] && sigma.shape() == &[b, c][..],
            "target shapes {:?}/{:?} don't match batch [{b},{c},{d}]",
            y_star.shape(),
            sigma.shape()
        );
        let head_targets = |j: usize| -> Tensor {
            let mut t = Tensor::zeros(&[b, d]);
            for bi in 0..b {
                let off = (bi * c + j) * d;
                t.row_mut(bi).copy_from_slice(&y_star.data()[off..off + d]);
            }
            t
        };
        let sigma_col = |j: usize| -> Tensor {
            let mut t = Tensor::zeros(&[b]);
            for bi in 0..b {
                t.data_mut()[bi] = sigma.row(bi)[j];
            }
            t
        };
        // per-head weight turning mean-over-elements into the paper's
        // mean over (B, c) of the per-head d-dim squared sum
        let head_weight = d as f32 / c as f32;

        let mut tape = Tape::new();
        let pids = self.param_leaves(&mut tape);
        let acc = |tape: &mut Tape, acc: Option<NodeId>, n: NodeId| -> Option<NodeId> {
            Some(match acc {
                Some(a) => tape.add(a, n),
                None => n,
            })
        };

        let (total, parts) = match self.spec.model {
            ModelKind::SupportNet => {
                let (scores, key_nodes) = self.build_supportnet(&mut tape, &pids, x, true);
                let sig_leaf = tape.leaf(sigma.clone());
                let ds = tape.sub(scores, sig_leaf);
                let sq = tape.square(ds);
                let l_score = tape.mean_all(sq);
                let mut l_grad: Option<NodeId> = None;
                for (j, kn) in key_nodes.iter().enumerate() {
                    let yj = tape.leaf(head_targets(j));
                    let dj = tape.sub(*kn, yj);
                    let sqj = tape.square(dj);
                    let mj = tape.mean_all(sqj);
                    let wj = tape.scale(mj, head_weight);
                    l_grad = acc(&mut tape, l_grad, wj);
                }
                let l_grad = l_grad.expect("c >= 1");
                let mut pen: Option<NodeId> = None;
                for idx in self.spec.icnn_penalty_indices() {
                    let p = tape.neg_part_sq(pids[idx]);
                    pen = acc(&mut tape, pen, p);
                }
                let pen = pen.expect("supportnet has wz/wout penalty targets");
                let ta = tape.scale(l_score, lam.lam_a);
                let tb = tape.scale(l_grad, lam.lam_b);
                let tp = tape.scale(pen, lam.lam_icnn);
                let tab = tape.add(ta, tb);
                let total = tape.add(tab, tp);
                let parts = LossParts {
                    total: tape.scalar(total),
                    loss_a: tape.scalar(l_score),
                    loss_b: tape.scalar(l_grad),
                    penalty: tape.scalar(pen),
                };
                (total, parts)
            }
            ModelKind::KeyNet => {
                let x_leaf = tape.leaf(x.clone());
                let trunk = self.build_trunk(&mut tape, &pids, x_leaf);
                let mut l_key: Option<NodeId> = None;
                let mut l_consist: Option<NodeId> = None;
                for j in 0..c {
                    let kj = tape.slice_cols(trunk.out, j * d, d);
                    let yj = tape.leaf(head_targets(j));
                    let dj = tape.sub(kj, yj);
                    let sqj = tape.square(dj);
                    let mj = tape.mean_all(sqj);
                    let wj = tape.scale(mj, head_weight);
                    l_key = acc(&mut tape, l_key, wj);

                    let sj = tape.row_dot(kj, x_leaf); // Euler score ⟨F_j, x⟩
                    let sig_leaf = tape.leaf(sigma_col(j));
                    let dsj = tape.sub(sj, sig_leaf);
                    let sqs = tape.square(dsj);
                    let ms = tape.mean_all(sqs);
                    let ws = tape.scale(ms, 1.0 / c as f32);
                    l_consist = acc(&mut tape, l_consist, ws);
                }
                let (l_key, l_consist) = (l_key.expect("c >= 1"), l_consist.expect("c >= 1"));
                let tb = tape.scale(l_key, lam.lam_b);
                let ta = tape.scale(l_consist, lam.lam_a);
                let total = tape.add(tb, ta);
                let parts = LossParts {
                    total: tape.scalar(total),
                    loss_a: tape.scalar(l_key),
                    loss_b: tape.scalar(l_consist),
                    penalty: 0.0,
                };
                (total, parts)
            }
        };
        let grads = tape.grad(total, &pids);
        Ok((parts, grads))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::normalize_rows;
    use crate::util::Rng;

    fn unit(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        normalize_rows(&mut t);
        t
    }

    fn targets(n: usize, c: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
        let x = unit(&[n, d], seed);
        let y = unit(&[n * c, d], seed ^ 1).reshape(&[n, c, d]);
        let mut s = Tensor::zeros(&[n, c]);
        Rng::new(seed ^ 2).fill_normal(s.data_mut(), 0.3);
        (x, y, s)
    }

    #[test]
    fn keynet_scores_are_euler_consistent() {
        let spec = NetSpec::new(ModelKind::KeyNet, 6, 2, 8, 3);
        let net = Network::init(spec, 3).unwrap();
        let x = unit(&[5, 6], 4);
        let (scores, keys) = net.scores_and_keys(&x).unwrap();
        assert_eq!(scores.shape(), &[5, 2]);
        assert_eq!(keys.shape(), &[5, 2, 6]);
        for b in 0..5 {
            for j in 0..2 {
                let off = (b * 2 + j) * 6;
                let dotv: f32 = keys.data()[off..off + 6]
                    .iter()
                    .zip(x.row(b))
                    .map(|(a, q)| a * q)
                    .sum();
                assert!((dotv - scores.row(b)[j]).abs() < 1e-5);
            }
        }
        // scores() agrees with scores_and_keys()
        let alone = net.scores(&x).unwrap();
        assert_eq!(alone.data(), scores.data());
    }

    #[test]
    fn supportnet_homogenized_satisfies_euler() {
        let spec = NetSpec::new(ModelKind::SupportNet, 5, 2, 8, 3);
        let net = Network::init(spec, 7).unwrap();
        let x = unit(&[4, 5], 8);
        let (scores, keys) = net.scores_and_keys(&x).unwrap();
        for b in 0..4 {
            for j in 0..2 {
                let off = (b * 2 + j) * 5;
                let dotv: f32 = keys.data()[off..off + 5]
                    .iter()
                    .zip(x.row(b))
                    .map(|(a, q)| a * q)
                    .sum();
                let s = scores.row(b)[j];
                assert!(
                    (dotv - s).abs() < 1e-4 * (1.0 + s.abs()),
                    "Euler violated: <grad,x>={dotv} vs f={s}"
                );
            }
        }
    }

    #[test]
    fn supportnet_scores_positively_homogeneous() {
        let spec = NetSpec::new(ModelKind::SupportNet, 6, 1, 8, 2);
        let net = Network::init(spec, 9).unwrap();
        let x = unit(&[3, 6], 10);
        let mut x2 = x.clone();
        for v in x2.data_mut() {
            *v *= 2.5;
        }
        let s1 = net.scores(&x).unwrap();
        let s2 = net.scores(&x2).unwrap();
        for (a, b) in s1.data().iter().zip(s2.data()) {
            assert!((b - 2.5 * a).abs() < 1e-4 * (1.0 + a.abs()), "{b} vs 2.5*{a}");
        }
    }

    #[test]
    fn loss_and_grads_shapes_and_finiteness() {
        for kind in [ModelKind::SupportNet, ModelKind::KeyNet] {
            let spec = NetSpec::new(kind, 4, 2, 6, 3);
            let net = Network::init(spec.clone(), 11).unwrap();
            let (x, y, s) = targets(3, 2, 4, 12);
            let lam = Lambdas {
                lam_a: 0.01,
                lam_b: 1.0,
                lam_icnn: 1e-4,
            };
            let (parts, grads) = net.loss_and_grads(&x, &y, &s, &lam).unwrap();
            assert!(parts.total.is_finite() && parts.total > 0.0, "{kind:?}");
            assert_eq!(grads.len(), spec.param_specs().len());
            for (g, (name, shape)) in grads.iter().zip(spec.param_specs()) {
                assert_eq!(g.shape(), &shape[..], "{kind:?} {name}");
                assert!(g.data().iter().all(|v| v.is_finite()), "{kind:?} {name}");
            }
            // the loss must touch every parameter except (possibly) the
            // zero-initialized head bias of the supportnet score path
            let touched = grads
                .iter()
                .filter(|g| g.data().iter().any(|&v| v != 0.0))
                .count();
            assert!(touched >= grads.len() - 1, "{kind:?}: {touched} touched");
        }
    }

    #[test]
    fn bad_shapes_are_typed_errors() {
        let spec = NetSpec::new(ModelKind::KeyNet, 4, 1, 6, 2);
        let net = Network::init(spec, 1).unwrap();
        assert!(net.scores(&unit(&[2, 5], 2)).is_err());
        let (x, y, s) = targets(3, 1, 4, 3);
        let bad_y = unit(&[3, 5], 4).reshape(&[3, 1, 5]);
        assert!(net
            .loss_and_grads(&bad_y, &y, &s, &Lambdas { lam_a: 0.0, lam_b: 1.0, lam_icnn: 0.0 })
            .is_err());
        assert!(net
            .loss_and_grads(&x, &bad_y, &s, &Lambdas { lam_a: 0.0, lam_b: 1.0, lam_icnn: 0.0 })
            .is_err());
        // mismatched param shapes rejected at construction
        let spec2 = NetSpec::new(ModelKind::KeyNet, 4, 1, 6, 2);
        let mut params = spec2.init_params(5);
        params[0] = Tensor::zeros(&[4, 7]);
        assert!(Network::new(spec2, params).is_err());
    }
}
