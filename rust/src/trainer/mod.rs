//! Rust-driven training: the Adam step itself is the AOT-compiled
//! `<config>.train` artifact (L2), this module owns everything around it
//! — batch sampling, the step loop, EMA parameter extraction, validation
//! curves, and checkpoint caching shared by the benches.

pub mod curves;
#[allow(clippy::module_inception)]
pub mod trainer;

pub use curves::{CurvePoint, EvalPoint, TrainingCurve};
pub use trainer::{train, train_or_load, TrainOpts, TrainOutcome};
