//! Training drivers for the learned models. The default build trains
//! entirely in Rust ([`rust`]): batch sampling, the manual-backprop
//! losses from [`crate::nn`], Adam + warmup/cosine + EMA, validation
//! curves. With the `xla` feature the same [`TrainOpts`] drive the
//! AOT-compiled train step instead ([`trainer`], unchanged from the
//! original PJRT path) — XLA is an optional accelerator backend, not a
//! prerequisite.

pub mod curves;
mod opts;
pub mod rust;
#[cfg(feature = "xla")]
#[allow(clippy::module_inception)]
pub mod trainer;

pub use curves::{CurvePoint, EvalPoint, TrainingCurve};
pub use opts::TrainOpts;
pub use rust::{validation_retrieval, RustTrainOutcome};
#[cfg(feature = "xla")]
pub use trainer::{train, train_or_load, TrainOutcome};
