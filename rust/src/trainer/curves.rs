//! Training/validation curves (paper Figs. 9, 11, 15): plain data
//! holders plus text rendering for the bench reports.

/// One logged optimization point.
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    pub step: usize,
    pub loss: f32,
    /// SupportNet: score loss; KeyNet: key loss.
    pub loss_a: f32,
    /// SupportNet: grad loss; KeyNet: consistency loss.
    pub loss_b: f32,
}

/// One validation checkpoint.
#[derive(Clone, Copy, Debug)]
pub struct EvalPoint {
    pub step: usize,
    /// Relative transport error E_rel (Eq. 4.1), log scale.
    pub e_rel: f32,
    pub mse_key: f32,
    pub mse_score: f32,
}

/// Full training trajectory.
#[derive(Clone, Debug, Default)]
pub struct TrainingCurve {
    pub train: Vec<CurvePoint>,
    pub eval: Vec<EvalPoint>,
}

impl TrainingCurve {
    pub fn final_e_rel(&self) -> Option<f32> {
        self.eval.last().map(|e| e.e_rel)
    }

    pub fn final_loss(&self) -> Option<f32> {
        self.train.last().map(|p| p.loss)
    }

    /// ASCII sparkline of E_rel over training (bench reports).
    pub fn e_rel_sparkline(&self) -> String {
        const BARS: &[char] = &['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        if self.eval.is_empty() {
            return String::new();
        }
        let vals: Vec<f32> = self.eval.iter().map(|e| e.e_rel).collect();
        let (lo, hi) = vals
            .iter()
            .fold((f32::MAX, f32::MIN), |(l, h), &v| (l.min(v), h.max(v)));
        let span = (hi - lo).max(1e-9);
        vals.iter()
            .map(|v| BARS[(((v - lo) / span) * 7.0).round() as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparkline_len_matches_points() {
        let mut c = TrainingCurve::default();
        for (i, v) in [0.5f32, 0.0, -0.5, -1.0].iter().enumerate() {
            c.eval.push(EvalPoint {
                step: i,
                e_rel: *v,
                mse_key: 0.0,
                mse_score: 0.0,
            });
        }
        assert_eq!(c.e_rel_sparkline().chars().count(), 4);
        assert_eq!(c.final_e_rel(), Some(-1.0));
    }

    #[test]
    fn empty_curve_safe() {
        let c = TrainingCurve::default();
        assert!(c.e_rel_sparkline().is_empty());
        assert_eq!(c.final_e_rel(), None);
    }
}
