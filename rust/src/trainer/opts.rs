//! Training hyperparameters shared by both backends (the pure-Rust loop
//! in [`crate::trainer::rust`] and the AOT/PJRT loop behind the `xla`
//! feature).

/// Training hyperparameters (paper Sec. 3.2/4.1). The loss lambdas
/// follow the uniform (a, b) convention of the train step:
/// SupportNet `lam_a`=score / `lam_b`=gradient-matching;
/// KeyNet `lam_a`=consistency / `lam_b`=key regression.
#[derive(Clone, Debug)]
pub struct TrainOpts {
    pub steps: usize,
    pub peak_lr: f32,
    /// SupportNet: lam_score; KeyNet: lam_consist (paper default 0.01).
    pub lam_a: f32,
    /// SupportNet: lam_grad; KeyNet: lam_key (paper default 1.0).
    pub lam_b: f32,
    /// ICNN non-negativity penalty weight (SupportNet).
    pub lam_icnn: f32,
    pub ema_decay: f32,
    pub warmup_frac: f32,
    pub weight_decay: f32,
    pub seed: u64,
    /// Batch size for the pure-Rust loop (the AOT loop's batch is baked
    /// into its exported artifacts as `meta.train_batch`).
    pub batch: usize,
    /// Evaluate every `eval_every` steps (0 = only at the end).
    pub eval_every: usize,
    /// Log a train point every `log_every` steps.
    pub log_every: usize,
    pub verbose: bool,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            steps: 1200,
            peak_lr: 1e-2,
            lam_a: 0.01,
            lam_b: 1.0,
            lam_icnn: 1e-4,
            ema_decay: 0.995,
            warmup_frac: 0.025,
            weight_decay: 0.0,
            seed: 7,
            batch: 256,
            eval_every: 200,
            log_every: 50,
            verbose: false,
        }
    }
}
