//! The training loop driver (paper Sec. 3.3 / 4.1, scaled to this
//! testbed — DESIGN.md §3 substitution table).

use anyhow::{bail, Result};
use std::path::PathBuf;

use crate::data::Dataset;
use crate::model::ParamSet;
use crate::runtime::engine::{lit_f32, lit_scalar_u32, literal_to_vec, Engine};
use crate::runtime::ArtifactMeta;
use crate::tensor::Tensor;
use crate::trainer::curves::{CurvePoint, EvalPoint, TrainingCurve};
use crate::trainer::TrainOpts;
use crate::util::Rng;

/// Result of a training run.
pub struct TrainOutcome {
    /// EMA parameters (what the paper evaluates).
    pub params: ParamSet,
    pub curve: TrainingCurve,
    pub steps: usize,
}

fn shapes_of(meta: &ArtifactMeta) -> Vec<Vec<usize>> {
    meta.params.iter().map(|(_, s)| s.clone()).collect()
}

/// Build the padded eval batch literals (x, y*, sigma) once.
fn eval_batch_literals(
    meta: &ArtifactMeta,
    ds: &Dataset,
) -> Result<(xla::Literal, xla::Literal, xla::Literal)> {
    let be = meta.eval_batch;
    let (d, c) = (meta.d, meta.c);
    let nval = ds.val.x.rows();
    anyhow::ensure!(nval > 0, "empty validation set");
    let idx: Vec<usize> = (0..be).map(|i| i % nval).collect();
    let (mut x, mut y, mut s) = (Vec::new(), Vec::new(), Vec::new());
    ds.batch(&ds.val, &idx, &mut x, &mut y, &mut s);
    Ok((
        lit_f32(&[be, d], &x)?,
        lit_f32(&[be, c, d], &y)?,
        lit_f32(&[be, c], &s)?,
    ))
}

/// Extract the EMA parameter block from the state literals.
fn ema_params(meta: &ArtifactMeta, state: &[xla::Literal]) -> Result<ParamSet> {
    let p = meta.n_param_tensors;
    let shapes = shapes_of(meta);
    let mut tensors = Vec::with_capacity(p);
    for (i, shape) in shapes.iter().enumerate() {
        let v = literal_to_vec(&state[3 * p + i])?;
        tensors.push(Tensor::from_vec(shape, v));
    }
    Ok(ParamSet { tensors })
}

/// Run the full training loop for `meta` on `ds`.
pub fn train(engine: &Engine, meta: &ArtifactMeta, ds: &Dataset, opts: &TrainOpts) -> Result<TrainOutcome> {
    if ds.c != meta.c {
        bail!(
            "dataset prepared with c={} but model {} wants c={}",
            ds.c,
            meta.name,
            meta.c
        );
    }
    if ds.d() != meta.d {
        bail!("dataset d={} vs model d={}", ds.d(), meta.d);
    }
    let init = engine.load(&format!("{}.init", meta.name))?;
    let step_exe = engine.load(&format!("{}.train", meta.name))?;
    let eval_exe = engine.load(&format!("{}.eval", meta.name))?;

    // state <- init(seed)
    let seed_lit = lit_scalar_u32(opts.seed as u32)?;
    let mut state = init.run(&[&seed_lit])?;
    anyhow::ensure!(
        state.len() == meta.n_state_tensors,
        "init returned {} tensors, meta wants {}",
        state.len(),
        meta.n_state_tensors
    );

    let hparams = lit_f32(
        &[8],
        &[
            opts.lam_a,
            opts.lam_b,
            opts.lam_icnn,
            opts.peak_lr,
            opts.steps as f32,
            opts.warmup_frac,
            opts.ema_decay,
            opts.weight_decay,
        ],
    )?;

    let b = meta.train_batch;
    let (d, c) = (meta.d, meta.c);
    let n_train = ds.train.x.rows();
    anyhow::ensure!(n_train > 0, "empty train set");
    let mut rng = Rng::new(opts.seed ^ 0xBA7C4);
    let (ex, ey, es) = eval_batch_literals(meta, ds)?;

    let mut curve = TrainingCurve::default();
    let (mut xb, mut yb, mut sb) = (Vec::new(), Vec::new(), Vec::new());
    let mut indices = vec![0usize; b];

    for step in 0..opts.steps {
        for i in indices.iter_mut() {
            *i = rng.below(n_train);
        }
        ds.batch(&ds.train, &indices, &mut xb, &mut yb, &mut sb);
        let xl = lit_f32(&[b, d], &xb)?;
        let yl = lit_f32(&[b, c, d], &yb)?;
        let sl = lit_f32(&[b, c], &sb)?;

        let mut inputs: Vec<&xla::Literal> = state.iter().collect();
        inputs.push(&xl);
        inputs.push(&yl);
        inputs.push(&sl);
        inputs.push(&hparams);
        let mut out = step_exe.run(&inputs)?;
        let metrics_lit = out.pop().unwrap();
        state = out;

        let log_now = opts.log_every > 0 && (step % opts.log_every == 0 || step + 1 == opts.steps);
        if log_now {
            let m = literal_to_vec(&metrics_lit)?;
            curve.train.push(CurvePoint {
                step,
                loss: m[0],
                loss_a: m[1],
                loss_b: m[2],
            });
            if opts.verbose {
                eprintln!(
                    "[{}] step {step}/{} loss {:.5} a {:.5} b {:.5}",
                    meta.name, opts.steps, m[0], m[1], m[2]
                );
            }
        }

        let eval_now = (opts.eval_every > 0 && step > 0 && step % opts.eval_every == 0)
            || step + 1 == opts.steps;
        if eval_now {
            let p = meta.n_param_tensors;
            let mut inputs: Vec<&xla::Literal> = state[3 * p..4 * p].iter().collect();
            inputs.push(&ex);
            inputs.push(&ey);
            inputs.push(&es);
            let out = eval_exe.run(&inputs)?;
            let m = literal_to_vec(&out[0])?;
            curve.eval.push(EvalPoint {
                step,
                e_rel: m[0],
                mse_key: m[1],
                mse_score: m[2],
            });
            if opts.verbose {
                eprintln!(
                    "[{}] eval @ {step}: E_rel {:.4} mse_key {:.5} mse_score {:.5}",
                    meta.name, m[0], m[1], m[2]
                );
            }
        }
    }

    let params = ema_params(meta, &state)?;
    Ok(TrainOutcome {
        params,
        curve,
        steps: opts.steps,
    })
}

/// Checkpoint path for a (config, steps, seed, lambda) combination.
pub fn checkpoint_path(dir: &std::path::Path, meta: &ArtifactMeta, opts: &TrainOpts) -> PathBuf {
    // lambdas are part of the identity so the Fig-14 ablation caches
    // separately per configuration.
    let tag = format!(
        "{}.s{}.seed{}.la{:.0e}.lb{:.0e}.lr{:.0e}",
        meta.name, opts.steps, opts.seed, opts.lam_a, opts.lam_b, opts.peak_lr
    );
    dir.join("checkpoints").join(format!("{tag}.amts"))
}

/// Train unless a cached checkpoint exists (benches share models).
pub fn train_or_load(
    engine: &Engine,
    meta: &ArtifactMeta,
    ds: &Dataset,
    opts: &TrainOpts,
) -> Result<TrainOutcome> {
    let path = checkpoint_path(engine.dir(), meta, opts);
    if path.exists() {
        if let Ok(params) = ParamSet::load(meta, &path) {
            return Ok(TrainOutcome {
                params,
                curve: TrainingCurve::default(),
                steps: opts.steps,
            });
        }
        // corrupt / stale checkpoint -> retrain below
    }
    let out = train(engine, meta, ds, opts)?;
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    out.params.save(meta, &path)?;
    Ok(out)
}
