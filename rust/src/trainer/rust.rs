//! The pure-Rust training loop: Adam with linear warmup + cosine decay
//! and an EMA parameter trail (paper Sec. 4.1), driving the
//! [`crate::nn`] losses — score regression + gradient matching for
//! SupportNet, key regression + Euler score-consistency for KeyNet —
//! over batches sampled exactly like the AOT loop. This is what makes
//! `amips train` work in the default build; the `xla` feature swaps in
//! the AOT-compiled step with the same [`TrainOpts`].

use anyhow::{bail, Result};

use crate::data::Dataset;
use crate::metrics::retrieval::{self, RetrievalMetrics};
use crate::metrics::transport;
use crate::model::{AmortizedModel, RustModel};
use crate::nn::{Lambdas, NetSpec, Network};
use crate::tensor::Tensor;
use crate::trainer::curves::{CurvePoint, EvalPoint, TrainingCurve};
use crate::trainer::TrainOpts;
use crate::util::Rng;

const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// Result of a pure-Rust training run.
pub struct RustTrainOutcome {
    /// Model carrying the EMA parameters (what the paper evaluates).
    pub model: RustModel,
    pub curve: TrainingCurve,
    pub steps: usize,
}

/// Cosine decay with linear warmup (mirrors `python/compile/train.py`).
fn lr_schedule(step: usize, total: usize, warmup_frac: f32, peak: f32) -> f32 {
    let total = total as f32;
    let warm = (total * warmup_frac).max(1.0);
    let step = step as f32;
    if step < warm {
        peak * (step + 1.0) / warm
    } else {
        let prog = ((step - warm) / (total - warm).max(1.0)).clamp(0.0, 1.0);
        0.5 * peak * (1.0 + (std::f32::consts::PI * prog).cos())
    }
}

/// Validation metrics on `(x, y*, σ)` with the given parameters.
fn eval_metrics(net: &Network, x: &Tensor, y_star: &Tensor, sigma: &Tensor) -> Result<EvalMats> {
    let (scores, keys) = net.scores_and_keys(x)?;
    let (n, c) = (sigma.rows(), sigma.row_width());
    let d = x.row_width();
    let e_rel = transport::relative_transport_error_clustered(&keys, x, y_star) as f32;
    let mut mse_key = 0.0f64;
    for bi in 0..n {
        for j in 0..c {
            let off = (bi * c + j) * d;
            let mut s = 0.0f64;
            for e in 0..d {
                s += ((keys.data()[off + e] - y_star.data()[off + e]) as f64).powi(2);
            }
            mse_key += s;
        }
    }
    let mse_key = (mse_key / (n * c) as f64) as f32;
    let mut mse_score = 0.0f64;
    for (s, t) in scores.data().iter().zip(sigma.data()) {
        mse_score += ((s - t) as f64).powi(2);
    }
    let mse_score = (mse_score / (n * c) as f64) as f32;
    Ok(EvalMats {
        e_rel,
        mse_key,
        mse_score,
    })
}

struct EvalMats {
    e_rel: f32,
    mse_key: f32,
    mse_score: f32,
}

/// Train `spec` on `ds` with the pure-Rust backend.
pub fn train(spec: &NetSpec, label: &str, ds: &Dataset, opts: &TrainOpts) -> Result<RustTrainOutcome> {
    spec.validate()?;
    if ds.c != spec.c {
        bail!(
            "dataset prepared with c={} but model {label} wants c={}",
            ds.c,
            spec.c
        );
    }
    if ds.d() != spec.d {
        bail!("dataset d={} vs model d={}", ds.d(), spec.d);
    }
    let n_train = ds.train.x.rows();
    anyhow::ensure!(n_train > 0, "empty train set");
    anyhow::ensure!(ds.val.x.rows() > 0, "empty validation set");
    anyhow::ensure!(opts.batch > 0, "batch size must be >= 1");

    let (b, c, d) = (opts.batch, spec.c, spec.d);
    let lam = Lambdas {
        lam_a: opts.lam_a,
        lam_b: opts.lam_b,
        lam_icnn: opts.lam_icnn,
    };
    let mut net = Network::init(spec.clone(), opts.seed)?;
    let n_tensors = net.params().len();
    let mut m: Vec<Tensor> = net.params().iter().map(|p| Tensor::zeros(p.shape())).collect();
    let mut v: Vec<Tensor> = m.clone();
    let mut ema: Vec<Tensor> = net.params().to_vec();

    // fixed validation batch (the whole held-out set), mirrored from the
    // AOT loop's padded eval batch
    let nval = ds.val.x.rows();
    let val_idx: Vec<usize> = (0..nval).collect();
    let (mut xv, mut yv, mut sv) = (Vec::new(), Vec::new(), Vec::new());
    ds.batch(&ds.val, &val_idx, &mut xv, &mut yv, &mut sv);
    let val_x = Tensor::from_vec(&[nval, d], xv);
    let val_y = Tensor::from_vec(&[nval, c, d], yv);
    let val_s = Tensor::from_vec(&[nval, c], sv);

    let mut rng = Rng::new(opts.seed ^ 0xBA7C4);
    let mut curve = TrainingCurve::default();
    let (mut xb, mut yb, mut sb) = (Vec::new(), Vec::new(), Vec::new());
    let mut indices = vec![0usize; b];

    for step in 0..opts.steps {
        for i in indices.iter_mut() {
            *i = rng.below(n_train);
        }
        ds.batch(&ds.train, &indices, &mut xb, &mut yb, &mut sb);
        let x = Tensor::from_vec(&[b, d], xb.clone());
        let y = Tensor::from_vec(&[b, c, d], yb.clone());
        let s = Tensor::from_vec(&[b, c], sb.clone());

        let (parts, grads) = net.loss_and_grads(&x, &y, &s, &lam)?;

        let lr = lr_schedule(step, opts.steps, opts.warmup_frac, opts.peak_lr);
        let t = (step + 1) as f32;
        let bc1 = 1.0 - ADAM_B1.powf(t);
        let bc2 = 1.0 - ADAM_B2.powf(t);
        let params = net.params_mut();
        for i in 0..n_tensors {
            let g = grads[i].data();
            let pm = params[i].data_mut();
            let mi = m[i].data_mut();
            let vi = v[i].data_mut();
            let ei = ema[i].data_mut();
            for e in 0..g.len() {
                let ge = g[e];
                mi[e] = ADAM_B1 * mi[e] + (1.0 - ADAM_B1) * ge;
                vi[e] = ADAM_B2 * vi[e] + (1.0 - ADAM_B2) * ge * ge;
                let update = (mi[e] / bc1) / ((vi[e] / bc2).sqrt() + ADAM_EPS);
                pm[e] -= lr * (update + opts.weight_decay * pm[e]);
                ei[e] = opts.ema_decay * ei[e] + (1.0 - opts.ema_decay) * pm[e];
            }
        }

        let log_now = opts.log_every > 0 && (step % opts.log_every == 0 || step + 1 == opts.steps);
        if log_now {
            curve.train.push(CurvePoint {
                step,
                loss: parts.total,
                loss_a: parts.loss_a,
                loss_b: parts.loss_b,
            });
            if opts.verbose {
                eprintln!(
                    "[{label}] step {step}/{} loss {:.5} a {:.5} b {:.5}",
                    opts.steps, parts.total, parts.loss_a, parts.loss_b
                );
            }
        }

        let eval_now = (opts.eval_every > 0 && step > 0 && step % opts.eval_every == 0)
            || step + 1 == opts.steps;
        if eval_now {
            let eval_net = Network::new(spec.clone(), ema.clone())?;
            let ev = eval_metrics(&eval_net, &val_x, &val_y, &val_s)?;
            curve.eval.push(EvalPoint {
                step,
                e_rel: ev.e_rel,
                mse_key: ev.mse_key,
                mse_score: ev.mse_score,
            });
            if opts.verbose {
                eprintln!(
                    "[{label}] eval @ {step}: E_rel {:.4} mse_key {:.5} mse_score {:.5}",
                    ev.e_rel, ev.mse_key, ev.mse_score
                );
            }
        }
    }

    let model = RustModel::new(label, Network::new(spec.clone(), ema)?);
    Ok(RustTrainOutcome {
        model,
        curve,
        steps: opts.steps,
    })
}

/// End-to-end retrieval quality of a trained model on the validation
/// queries (paper Sec. 4.2): rank the predicted key against the whole
/// database. Returns the retrieval metrics plus the relative transport
/// error of the evaluated heads. For `c > 1` the true-cluster head is
/// evaluated (same protocol as `amips eval`).
pub fn validation_retrieval(
    model: &dyn AmortizedModel,
    ds: &Dataset,
) -> Result<(RetrievalMetrics, f64)> {
    anyhow::ensure!(
        model.n_heads() == ds.c,
        "model '{}' has c={} but the dataset was prepared with c={}",
        model.label(),
        model.n_heads(),
        ds.c
    );
    let (_scores, keys) = model.scores_and_keys(&ds.val.x)?;
    let n = ds.val.x.rows();
    let (c, d) = (model.n_heads(), model.dim());
    let mut pred = Tensor::zeros(&[n, d]);
    let mut targets = Vec::with_capacity(n);
    for q in 0..n {
        let j = if c > 1 { ds.val.gt.top_cluster(q) } else { 0 };
        let off = (q * c + j) * d;
        pred.row_mut(q).copy_from_slice(&keys.data()[off..off + d]);
        targets.push(ds.val.gt.global_top1(q).0);
    }
    let rm = retrieval::evaluate(&pred, &ds.keys, &targets);
    let tgt = ds.keys.gather_rows(&targets);
    let e_rel = transport::relative_transport_error(&pred, &ds.val.x, &tgt);
    Ok((rm, e_rel))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::PrepareOpts;
    use crate::data::CorpusSpec;
    use crate::nn::ModelKind;

    fn tiny_dataset(c: usize) -> Dataset {
        Dataset::prepare(
            &CorpusSpec {
                name: "trainer-unit".into(),
                n_keys: 120,
                d: 6,
                n_queries: 60,
                shift: 0.4,
                spread: 2.0,
                modes: 4,
                seed: 5,
            },
            &PrepareOpts {
                c,
                augment: 2,
                val_queries: 12,
                kmeans_restarts: 1,
                ..Default::default()
            },
        )
    }

    #[test]
    fn warmup_then_cosine_decay() {
        let peak = 1e-2;
        let lr0 = lr_schedule(0, 1000, 0.1, peak);
        let lr_peak = lr_schedule(100, 1000, 0.1, peak);
        let lr_end = lr_schedule(999, 1000, 0.1, peak);
        assert!(lr0 < lr_peak, "{lr0} vs {lr_peak}");
        assert!((lr_peak - peak).abs() / peak < 0.02);
        assert!(lr_end < 0.01 * peak, "{lr_end}");
    }

    #[test]
    fn short_run_reduces_loss_and_returns_curves() {
        let ds = tiny_dataset(1);
        let spec = NetSpec::new(ModelKind::KeyNet, 6, 1, 8, 2);
        let opts = TrainOpts {
            steps: 60,
            batch: 16,
            eval_every: 0,
            log_every: 10,
            ..TrainOpts::default()
        };
        let out = train(&spec, "unit.keynet", &ds, &opts).unwrap();
        assert_eq!(out.steps, 60);
        let first = out.curve.train.first().unwrap().loss;
        let last = out.curve.final_loss().unwrap();
        assert!(last.is_finite() && first.is_finite());
        assert!(last < first, "loss did not drop: {first} -> {last}");
        // final eval point exists even with eval_every = 0
        assert_eq!(out.curve.eval.len(), 1);
        let (rm, _) = validation_retrieval(&out.model, &ds).unwrap();
        assert_eq!(rm.n, 12);
    }

    #[test]
    fn mismatched_dataset_is_rejected() {
        let ds = tiny_dataset(1);
        let wrong_c = NetSpec::new(ModelKind::SupportNet, 6, 3, 8, 2);
        assert!(train(&wrong_c, "x", &ds, &TrainOpts::default()).is_err());
        let wrong_d = NetSpec::new(ModelKind::KeyNet, 7, 1, 8, 2);
        assert!(train(&wrong_d, "x", &ds, &TrainOpts::default()).is_err());
    }
}
