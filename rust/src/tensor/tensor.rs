//! A deliberately small dense tensor: row-major `f32`, shape up to rank 3.
//! It exists to carry embeddings/params between the substrates and the
//! PJRT boundary — not to be a general ndarray.

use anyhow::{bail, Result};
use std::io::{Read, Write};

use super::mapped::Section;

/// Row-major f32 tensor. The element storage is a [`Section`]: owned
/// RAM in every build path, or a borrowed view of an `Arc<Mapped>`
/// container region on the zero-copy artifact read paths. Views are
/// copy-on-write: any `&mut` access ([`Tensor::data_mut`],
/// [`Tensor::row_mut`]) silently materializes an owned copy first.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Section<f32>,
}

/// Magic header for the single-tensor binary format (`.amt`).
const MAGIC: &[u8; 4] = b"AMT1";
/// Magic for a named-tensor container (`.amts`): checkpoints, datasets.
const MAGIC_SET: &[u8; 4] = b"AMTS";
/// Upper bound on deserialized element counts: corrupt or hostile size
/// fields must fail fast instead of attempting a huge allocation (2^31
/// f32s = 8 GiB, far above anything this repo writes).
const MAX_ELEMS: usize = 1 << 31;

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: Section::owned(vec![0.0; shape.iter().product()]),
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} != data len {}",
            shape,
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data: Section::owned(data),
        }
    }

    /// Wrap a [`Section`] (owned or a borrowed container view) without
    /// copying. The zero-copy artifact readers build view tensors here.
    pub fn from_section(shape: &[usize], data: Section<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} != section len {}",
            shape,
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor {
            shape: vec![],
            data: Section::owned(vec![v]),
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }
    pub fn len(&self) -> usize {
        self.data.len()
    }
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
    pub fn data(&self) -> &[f32] {
        self.data.as_slice()
    }
    /// Mutable element access — copies a borrowed view first (COW).
    pub fn data_mut(&mut self) -> &mut [f32] {
        self.data.make_owned().as_mut_slice()
    }
    pub fn into_vec(self) -> Vec<f32> {
        self.data.into_vec()
    }
    /// Whether the elements are a borrowed view of a mapped container
    /// (zero-copy) rather than an owned RAM buffer.
    pub fn is_view(&self) -> bool {
        self.data.is_view()
    }
    /// Sequential-scan `madvise` hint for view-backed tensors (no-op
    /// when owned).
    pub fn advise_sequential(&self) {
        self.data.advise_sequential()
    }

    /// Number of rows when interpreted as a matrix [rows, cols].
    pub fn rows(&self) -> usize {
        if self.shape.is_empty() {
            1
        } else {
            self.shape[0]
        }
    }

    /// Row width = product of trailing dims.
    pub fn row_width(&self) -> usize {
        self.shape.iter().skip(1).product::<usize>().max(1)
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let w = self.row_width();
        &self.data.as_slice()[i * w..(i + 1) * w]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let w = self.row_width();
        &mut self.data.make_owned()[i * w..(i + 1) * w]
    }

    /// Reshape in place (element count must match).
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Gather rows by index into a new tensor.
    pub fn gather_rows(&self, idx: &[usize]) -> Tensor {
        let w = self.row_width();
        let mut out = Vec::with_capacity(idx.len() * w);
        for &i in idx {
            out.extend_from_slice(self.row(i));
        }
        let mut shape = self.shape.clone();
        shape[0] = idx.len();
        Tensor::from_vec(&shape, out)
    }

    // ------------------------------------------------------------------
    // Binary IO (.amt / .amts): little-endian, versioned by magic.
    // ------------------------------------------------------------------

    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_all(MAGIC)?;
        w.write_all(&(self.shape.len() as u32).to_le_bytes())?;
        for &d in &self.shape {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        // SAFETY-free byte copy of f32 LE data.
        let mut buf = Vec::with_capacity(self.data.len() * 4);
        for &v in self.data.as_slice() {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        w.write_all(&buf)?;
        Ok(())
    }

    pub fn read_from<R: Read>(r: &mut R) -> Result<Tensor> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad tensor magic {magic:?}");
        }
        let mut b4 = [0u8; 4];
        r.read_exact(&mut b4)?;
        let rank = u32::from_le_bytes(b4) as usize;
        if rank > 8 {
            bail!("implausible tensor rank {rank}");
        }
        let mut shape = Vec::with_capacity(rank);
        let mut b8 = [0u8; 8];
        for _ in 0..rank {
            r.read_exact(&mut b8)?;
            let dim = u64::from_le_bytes(b8);
            // zero dims would break the rows()*row_width()==len invariant
            // that row() relies on (no writer in this repo produces them)
            if dim == 0 || dim > MAX_ELEMS as u64 {
                bail!("implausible tensor dim {dim}");
            }
            shape.push(dim as usize);
        }
        let n = match shape.iter().try_fold(1usize, |acc, &d| acc.checked_mul(d)) {
            Some(n) if n <= MAX_ELEMS => n,
            _ => bail!("implausible tensor element count for shape {shape:?}"),
        };
        let mut raw = vec![0u8; n * 4];
        r.read_exact(&mut raw)?;
        let data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Tensor {
            shape,
            data: Section::owned(data),
        })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        self.write_to(&mut f)
    }

    pub fn load(path: &std::path::Path) -> Result<Tensor> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        Self::read_from(&mut f)
    }
}

/// Save a named tensor set (checkpoints, prepared datasets).
pub fn save_tensor_set(path: &std::path::Path, items: &[(String, &Tensor)]) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC_SET)?;
    f.write_all(&(items.len() as u32).to_le_bytes())?;
    for (name, t) in items {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u32).to_le_bytes())?;
        f.write_all(nb)?;
        t.write_to(&mut f)?;
    }
    Ok(())
}

/// Load a named tensor set.
pub fn load_tensor_set(path: &std::path::Path) -> Result<Vec<(String, Tensor)>> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC_SET {
        bail!("bad tensor-set magic {magic:?}");
    }
    let mut b4 = [0u8; 4];
    f.read_exact(&mut b4)?;
    let count = u32::from_le_bytes(b4) as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        f.read_exact(&mut b4)?;
        let nlen = u32::from_le_bytes(b4) as usize;
        let mut nb = vec![0u8; nlen];
        f.read_exact(&mut nb)?;
        let name = String::from_utf8(nb)?;
        let t = Tensor::read_from(&mut f)?;
        out.push((name, t));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_io() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let mut buf = Vec::new();
        t.write_to(&mut buf).unwrap();
        let back = Tensor::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn scalar_roundtrip() {
        let t = Tensor::scalar(4.25);
        let mut buf = Vec::new();
        t.write_to(&mut buf).unwrap();
        let back = Tensor::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back.shape(), &[] as &[usize]);
        assert_eq!(back.data()[0], 4.25);
    }

    #[test]
    fn bad_magic_rejected() {
        let buf = b"NOPE0000".to_vec();
        assert!(Tensor::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn implausible_sizes_rejected_without_allocating() {
        // crafted header with absurd dims must error, not abort on an
        // enormous (or overflow-wrapped) allocation
        for dims in [
            vec![1u64 << 33, 1u64 << 33],
            vec![1u64 << 40],
            vec![1 << 20, 1 << 20],
            vec![5, 0], // zero dims break the rows/row_width invariant
        ] {
            let mut buf = Vec::new();
            buf.extend_from_slice(MAGIC);
            buf.extend_from_slice(&(dims.len() as u32).to_le_bytes());
            for d in &dims {
                buf.extend_from_slice(&d.to_le_bytes());
            }
            assert!(Tensor::read_from(&mut buf.as_slice()).is_err(), "{dims:?}");
        }
    }

    #[test]
    fn rows_and_gather() {
        let t = Tensor::from_vec(&[3, 2], vec![0., 1., 10., 11., 20., 21.]);
        assert_eq!(t.row(1), &[10., 11.]);
        let g = t.gather_rows(&[2, 0]);
        assert_eq!(g.data(), &[20., 21., 0., 1.]);
        assert_eq!(g.shape(), &[2, 2]);
    }

    #[test]
    fn tensor_set_roundtrip() {
        let a = Tensor::from_vec(&[2], vec![1., 2.]);
        let b = Tensor::zeros(&[2, 2]);
        let dir = std::env::temp_dir().join("amips_test_set.amts");
        save_tensor_set(&dir, &[("a".into(), &a), ("b".into(), &b)]).unwrap();
        let back = load_tensor_set(&dir).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].0, "a");
        assert_eq!(back[0].1, a);
        assert_eq!(back[1].1, b);
        let _ = std::fs::remove_file(dir);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let _ = Tensor::from_vec(&[2, 2], vec![1.0]);
    }
}
