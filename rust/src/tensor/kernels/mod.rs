//! Runtime-dispatched SIMD kernels for the four hottest scoring loops:
//! the `dot` behind `gemm_nt_tile` and every scan, the PQ ADC
//! code-matrix scans (8-bit and 4-bit packed), the SQ8 dequant-dot, and
//! the `TopK::offer` pre-filter compare.
//!
//! # Tiers
//!
//! | tier      | arch    | gate                                              |
//! |-----------|---------|---------------------------------------------------|
//! | `avx2fma` | x86-64  | `is_x86_feature_detected!("avx2")` + `("fma")`    |
//! | `neon`    | aarch64 | `is_aarch64_feature_detected!("neon")`            |
//! | `scalar`  | any     | always available; forced by `AMIPS_FORCE_SCALAR=1`|
//!
//! The tier is detected once (first kernel call) and cached in an
//! atomic; `AMIPS_FORCE_SCALAR=1` in the environment pins the scalar
//! tier for the whole process, and [`force_scalar`] lets benches sweep
//! both dispatch modes in-process. The scalar tier is the exact
//! pre-dispatch kernel code, so it stays bit-identical to every
//! baseline produced before this layer existed.
//!
//! # Numerical contract
//!
//! Within one process the active tier never changes (detection is
//! cached), and the per-query and batched search paths call the same
//! kernel per (query, key) pair — so the PR 5 batched ≡ per-query
//! bit-identity contract holds *within every tier*. Across tiers, SIMD
//! re-association changes low-order bits; every tier `t` must satisfy,
//! for each kernel:
//!
//! ```text
//! |kernel_t(x) - kernel_scalar(x)| <= 16 · ε · Σᵢ |termᵢ|  (ε = f32::EPSILON)
//! ```
//!
//! where `termᵢ` are the products being summed (`aᵢ·bᵢ` for the dots,
//! table entries for the ADC scans), plus a 1e-6 absolute floor for
//! near-zero sums. NaN and ±inf propagate identically in kind: if the
//! scalar kernel returns NaN (any NaN term, or mixed-sign infinities),
//! every tier returns NaN; a single-signed infinite sum stays the same
//! signed infinity. `tests/properties.rs` enforces both clauses across
//! every available tier, remainder-lane dims included. The
//! `not_below_mask` pre-filter is exact (a comparison, not an
//! accumulation) and bit-identical across tiers.

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "aarch64")]
mod neon;
pub mod scalar;

use std::sync::atomic::{AtomicU8, Ordering};

/// A dispatch tier. `Scalar` is always available; the SIMD tiers exist
/// only on their architecture and only when the CPU reports the
/// features at runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Avx2Fma,
    Neon,
    Scalar,
}

impl Tier {
    /// Stable tier name, as reported in `BENCH_hotpath.json` rows and
    /// the `amips_build_info` metrics line: `avx2fma` / `neon` /
    /// `scalar`.
    pub fn name(self) -> &'static str {
        match self {
            Tier::Avx2Fma => "avx2fma",
            Tier::Neon => "neon",
            Tier::Scalar => "scalar",
        }
    }
}

const TIER_UNSET: u8 = 0;
const TIER_SCALAR: u8 = 1;
const TIER_AVX2: u8 = 2;
const TIER_NEON: u8 = 3;

/// Cached detection result (one of the `TIER_*` constants above).
static TIER: AtomicU8 = AtomicU8::new(TIER_UNSET);
/// Whether F16C conversion is available alongside the AVX2 tier
/// (0 unset / 1 no / 2 yes). All AVX2 parts ship F16C, but the gate is
/// a separate CPUID bit so it is detected separately.
static F16C: AtomicU8 = AtomicU8::new(0);

fn detect() -> u8 {
    if std::env::var("AMIPS_FORCE_SCALAR").map(|v| v == "1").unwrap_or(false) {
        return TIER_SCALAR;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return TIER_AVX2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return TIER_NEON;
        }
    }
    TIER_SCALAR
}

#[inline]
fn tier_code() -> u8 {
    let t = TIER.load(Ordering::Relaxed);
    if t != TIER_UNSET {
        return t;
    }
    let t = detect();
    TIER.store(t, Ordering::Relaxed);
    t
}

#[inline]
#[cfg(target_arch = "x86_64")]
fn has_f16c() -> bool {
    let f = F16C.load(Ordering::Relaxed);
    if f != 0 {
        return f == 2;
    }
    let yes = is_x86_feature_detected!("f16c");
    F16C.store(if yes { 2 } else { 1 }, Ordering::Relaxed);
    yes
}

/// The active dispatch tier (detected once, then cached).
#[inline]
pub fn tier() -> Tier {
    match tier_code() {
        TIER_AVX2 => Tier::Avx2Fma,
        TIER_NEON => Tier::Neon,
        _ => Tier::Scalar,
    }
}

/// The active tier's stable name (`avx2fma` / `neon` / `scalar`).
pub fn tier_name() -> &'static str {
    tier().name()
}

/// Pin (or unpin) the scalar tier for this process — the in-process
/// equivalent of `AMIPS_FORCE_SCALAR=1`, used by `perf_hotpath` to
/// sweep both dispatch modes into one artifact. `force_scalar(false)`
/// re-runs detection (which re-consults the environment) on the next
/// kernel call. Not safe to flip concurrently with result-comparing
/// work on other threads; tests that compare tiers use the `*_with`
/// entry points instead.
pub fn force_scalar(on: bool) {
    TIER.store(if on { TIER_SCALAR } else { TIER_UNSET }, Ordering::SeqCst);
}

/// Every tier the current host can execute, scalar first. Property
/// tests iterate this to compare each tier against scalar.
pub fn available_tiers() -> Vec<Tier> {
    let mut tiers = vec![Tier::Scalar];
    #[cfg(target_arch = "x86_64")]
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        tiers.push(Tier::Avx2Fma);
    }
    #[cfg(target_arch = "aarch64")]
    if std::arch::is_aarch64_feature_detected!("neon") {
        tiers.push(Tier::Neon);
    }
    tiers
}

#[cold]
fn unavailable(t: Tier) -> ! {
    panic!("kernel tier {t:?} is not available on this host");
}

// ---------------------------------------------------------------------------
// Dispatched kernels. Each has a `*_with(tier, ..)` twin that runs a
// specific tier (panicking if the host lacks it) so tests can compare
// tiers without mutating the global dispatch state.
// ---------------------------------------------------------------------------

/// Dispatched inner product — the single scoring kernel behind
/// `gemm_nt_tile`, every scan loop, and every exact re-rank.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    match tier_code() {
        #[cfg(target_arch = "x86_64")]
        TIER_AVX2 => unsafe { avx2::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        TIER_NEON => unsafe { neon::dot(a, b) },
        _ => scalar::dot(a, b),
    }
}

/// [`dot`] forced onto a specific tier (testing).
pub fn dot_with(t: Tier, a: &[f32], b: &[f32]) -> f32 {
    match t {
        Tier::Scalar => scalar::dot(a, b),
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2Fma if available_tiers().contains(&t) => unsafe { avx2::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon if available_tiers().contains(&t) => unsafe { neon::dot(a, b) },
        other => unavailable(other),
    }
}

/// Dispatched f16 dequant-dot (`storage=f16` key rows). The AVX2 tier
/// uses F16C expansion when the CPU has it; the NEON tier falls back to
/// the scalar kernel (conversion-only f16 support is not assumed).
#[inline]
pub fn dot_f16(a: &[f32], b: &[u16]) -> f32 {
    match tier_code() {
        #[cfg(target_arch = "x86_64")]
        TIER_AVX2 if has_f16c() => unsafe { avx2::dot_f16(a, b) },
        _ => scalar::dot_f16(a, b),
    }
}

/// [`dot_f16`] forced onto a specific tier (testing).
pub fn dot_f16_with(t: Tier, a: &[f32], b: &[u16]) -> f32 {
    match t {
        Tier::Scalar | Tier::Neon => scalar::dot_f16(a, b),
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2Fma if available_tiers().contains(&t) => {
            if has_f16c() {
                unsafe { avx2::dot_f16(a, b) }
            } else {
                scalar::dot_f16(a, b)
            }
        }
        other => unavailable(other),
    }
}

/// Dispatched SQ8 dequant-dot: `Σ qs[j] * code[j]` (the caller adds its
/// `<query, lo>` constant).
#[inline]
pub fn sq8_dot(qs: &[f32], code: &[u8]) -> f32 {
    match tier_code() {
        #[cfg(target_arch = "x86_64")]
        TIER_AVX2 => unsafe { avx2::sq8_dot(qs, code) },
        #[cfg(target_arch = "aarch64")]
        TIER_NEON => unsafe { neon::sq8_dot(qs, code) },
        _ => scalar::sq8_dot(qs, code),
    }
}

/// [`sq8_dot`] forced onto a specific tier (testing).
pub fn sq8_dot_with(t: Tier, qs: &[f32], code: &[u8]) -> f32 {
    match t {
        Tier::Scalar => scalar::sq8_dot(qs, code),
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2Fma if available_tiers().contains(&t) => unsafe { avx2::sq8_dot(qs, code) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon if available_tiers().contains(&t) => unsafe { neon::sq8_dot(qs, code) },
        other => unavailable(other),
    }
}

/// Dispatched 8-bit ADC scan: `Σ_sub table[sub * 256 + code[sub]]`
/// (table laid out `[m, 256]`). AVX2 gathers 8 entries per step; NEON
/// has no gather and uses the scalar loop.
#[inline]
pub fn adc_scan8(table: &[f32], code: &[u8]) -> f32 {
    match tier_code() {
        #[cfg(target_arch = "x86_64")]
        TIER_AVX2 => unsafe { avx2::adc_scan8(table, code) },
        _ => scalar::adc_scan8(table, code),
    }
}

/// [`adc_scan8`] forced onto a specific tier (testing).
pub fn adc_scan8_with(t: Tier, table: &[f32], code: &[u8]) -> f32 {
    match t {
        Tier::Scalar | Tier::Neon => scalar::adc_scan8(table, code),
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2Fma if available_tiers().contains(&t) => unsafe { avx2::adc_scan8(table, code) },
        other => unavailable(other),
    }
}

/// Dispatched 4-bit packed ADC scan (table laid out `[m, 16]`, two
/// subspace codes per byte, low nibble first).
#[inline]
pub fn adc_scan4(table: &[f32], packed: &[u8], m: usize) -> f32 {
    match tier_code() {
        #[cfg(target_arch = "x86_64")]
        TIER_AVX2 => unsafe { avx2::adc_scan4(table, packed, m) },
        _ => scalar::adc_scan4(table, packed, m),
    }
}

/// [`adc_scan4`] forced onto a specific tier (testing).
pub fn adc_scan4_with(t: Tier, table: &[f32], packed: &[u8], m: usize) -> f32 {
    match t {
        Tier::Scalar | Tier::Neon => scalar::adc_scan4(table, packed, m),
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2Fma if available_tiers().contains(&t) => unsafe {
            avx2::adc_scan4(table, packed, m)
        },
        other => unavailable(other),
    }
}

/// Dispatched `TopK::offer` pre-filter: bitmask of `chunk` entries NOT
/// strictly below `floor` (bit `i` ⇔ `!(chunk[i] < floor)`; NaN lanes
/// are kept, exactly the candidates `offer` forwards to `push`).
/// `chunk.len()` must be ≤ 32; SIMD paths cover the full-width lanes
/// and defer ragged chunks to the scalar loop. Exact on every tier.
#[inline]
pub fn not_below_mask(chunk: &[f32], floor: f32) -> u32 {
    match tier_code() {
        #[cfg(target_arch = "x86_64")]
        TIER_AVX2 if chunk.len() == 8 => unsafe { avx2::not_below_mask8(chunk, floor) },
        #[cfg(target_arch = "aarch64")]
        TIER_NEON if chunk.len() == 4 => unsafe { neon::not_below_mask4(chunk, floor) },
        _ => scalar::not_below_mask(chunk, floor),
    }
}

/// The chunk width [`not_below_mask`] can filter in one SIMD compare on
/// the active tier (8 on AVX2, 4 on NEON, 16 scalar — a cheap unrolled
/// loop either way).
#[inline]
pub fn prefilter_width() -> usize {
    match tier_code() {
        TIER_AVX2 => 8,
        TIER_NEON => 4,
        _ => 16,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::half::f16_from_f32;
    use crate::util::Rng;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        Rng::new(seed).fill_normal(&mut v, 1.0);
        v
    }

    fn tol(terms: impl Iterator<Item = f32>) -> f32 {
        16.0 * f32::EPSILON * terms.map(|t| t.abs()).sum::<f32>() + 1e-6
    }

    #[test]
    fn tier_name_is_stable() {
        assert_eq!(Tier::Avx2Fma.name(), "avx2fma");
        assert_eq!(Tier::Neon.name(), "neon");
        assert_eq!(Tier::Scalar.name(), "scalar");
        // whatever the host, the active tier is one of the published names
        assert!(["avx2fma", "neon", "scalar"].contains(&tier_name()));
        assert_eq!(available_tiers()[0], Tier::Scalar);
    }

    #[test]
    fn force_scalar_pins_and_releases() {
        let natural = tier();
        force_scalar(true);
        assert_eq!(tier(), Tier::Scalar);
        // the dispatched kernel now routes through the scalar tier
        let (a, b) = (randv(37, 1), randv(37, 2));
        assert_eq!(dot(&a, &b).to_bits(), scalar::dot(&a, &b).to_bits());
        force_scalar(false);
        assert_eq!(tier(), natural);
    }

    #[test]
    fn every_tier_matches_scalar_dot_within_tolerance() {
        for t in available_tiers() {
            for n in [0usize, 1, 3, 7, 8, 15, 16, 31, 32, 64, 100, 127] {
                let a = randv(n, 10 + n as u64);
                let b = randv(n, 20 + n as u64);
                let want = scalar::dot(&a, &b);
                let got = dot_with(t, &a, &b);
                let bound = tol(a.iter().zip(&b).map(|(x, y)| x * y));
                assert!(
                    (got - want).abs() <= bound,
                    "{t:?} n={n}: {got} vs {want} (bound {bound})"
                );
            }
        }
    }

    #[test]
    fn every_tier_matches_scalar_sq8_dot() {
        let mut rng = Rng::new(7);
        for t in available_tiers() {
            for n in [0usize, 1, 7, 8, 15, 16, 17, 33, 64, 100] {
                let qs = randv(n, 30 + n as u64);
                let code: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
                let want = scalar::sq8_dot(&qs, &code);
                let got = sq8_dot_with(t, &qs, &code);
                let bound = tol(qs.iter().zip(&code).map(|(x, &c)| x * c as f32));
                assert!(
                    (got - want).abs() <= bound,
                    "{t:?} n={n}: {got} vs {want} (bound {bound})"
                );
            }
        }
    }

    #[test]
    fn every_tier_matches_scalar_adc_scans() {
        let mut rng = Rng::new(8);
        for t in available_tiers() {
            for m in [1usize, 4, 7, 8, 9, 16, 24] {
                let table8 = randv(m * 256, 40 + m as u64);
                let code8: Vec<u8> = (0..m).map(|_| rng.below(256) as u8).collect();
                let want = scalar::adc_scan8(&table8, &code8);
                let got = adc_scan8_with(t, &table8, &code8);
                let bound = tol(code8.iter().enumerate().map(|(s, &c)| table8[s * 256 + c as usize]));
                assert!((got - want).abs() <= bound, "{t:?} adc8 m={m}");

                let table4 = randv(m * 16, 50 + m as u64);
                let packed: Vec<u8> = (0..m.div_ceil(2)).map(|_| rng.below(256) as u8).collect();
                let want = scalar::adc_scan4(&table4, &packed, m);
                let got = adc_scan4_with(t, &table4, &packed, m);
                assert!((got - want).abs() <= bound.max(1e-4), "{t:?} adc4 m={m}");
            }
        }
    }

    #[test]
    fn every_tier_matches_scalar_dot_f16() {
        for t in available_tiers() {
            for n in [0usize, 1, 7, 8, 15, 16, 17, 64, 100] {
                let a = randv(n, 60 + n as u64);
                let b: Vec<u16> = randv(n, 70 + n as u64)
                    .into_iter()
                    .map(f16_from_f32)
                    .collect();
                let want = scalar::dot_f16(&a, &b);
                let got = dot_f16_with(t, &a, &b);
                let bound = tol(a.iter().zip(&b).map(|(x, &h)| x * crate::tensor::half::f16_to_f32(h)));
                assert!(
                    (got - want).abs() <= bound,
                    "{t:?} n={n}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn not_below_mask_is_exact_on_every_tier_path() {
        // exercise both the SIMD full-chunk widths and ragged chunks
        let scores = [0.5f32, -1.0, f32::NAN, 0.0, 2.0, -0.5, 0.5, 3.0, 1.0];
        for floor in [f32::NEG_INFINITY, -0.5, 0.0, 0.5, 10.0] {
            for len in 0..=scores.len() {
                let chunk = &scores[..len];
                let want = scalar::not_below_mask(chunk, floor);
                assert_eq!(not_below_mask(chunk, floor), want, "len={len} floor={floor}");
            }
        }
    }

    #[test]
    fn nan_and_inf_propagate_in_kind() {
        for t in available_tiers() {
            for n in [1usize, 5, 8, 33, 100] {
                // one NaN term anywhere -> NaN on every tier
                let mut a = randv(n, 80 + n as u64);
                let b = randv(n, 90 + n as u64);
                a[n / 2] = f32::NAN;
                assert!(dot_with(t, &a, &b).is_nan(), "{t:?} NaN n={n}");
                // a single +inf product (all other terms finite) -> +inf
                let mut a = randv(n, 81 + n as u64);
                a[n / 2] = f32::INFINITY;
                let mut b = randv(n, 91 + n as u64);
                b[n / 2] = 1.0;
                let got = dot_with(t, &a, &b);
                assert!(
                    got.is_infinite() && got.is_sign_positive(),
                    "{t:?} inf n={n}: {got}"
                );
            }
        }
    }
}
