//! NEON kernel tier (aarch64). NEON is architecturally mandatory on
//! aarch64, but the dispatch wrappers in `crate::tensor::kernels` still
//! verify `is_aarch64_feature_detected!("neon")` before taking this
//! path — that plus in-bounds pointer arithmetic is the safety argument
//! for the `unsafe` here.
//!
//! Like the AVX2 tier, these kernels re-associate the reduction (4-lane
//! FMA accumulators + `vaddvq` horizontal sums) and satisfy the
//! tolerance contract in `crate::tensor::kernels`, not bit-identity.
//! NEON has no gather instruction, so the ADC scans and the f16
//! dequant-dot fall back to the scalar kernels inside this tier (the
//! fallback is per-kernel and deterministic, so batched ≡ per-query
//! still holds).

#![cfg(target_arch = "aarch64")]

use core::arch::aarch64::*;

/// 16-wide blocked dot: four 4-lane FMA accumulators, a 4-wide cleanup
/// loop, `vaddvq` horizontal sums, and a sequential scalar tail.
///
/// # Safety
/// Requires NEON at runtime; `a.len() == b.len()`.
#[target_feature(enable = "neon")]
pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut acc2 = vdupq_n_f32(0.0);
    let mut acc3 = vdupq_n_f32(0.0);
    let mut i = 0usize;
    while i + 16 <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i)));
        acc1 = vfmaq_f32(acc1, vld1q_f32(ap.add(i + 4)), vld1q_f32(bp.add(i + 4)));
        acc2 = vfmaq_f32(acc2, vld1q_f32(ap.add(i + 8)), vld1q_f32(bp.add(i + 8)));
        acc3 = vfmaq_f32(acc3, vld1q_f32(ap.add(i + 12)), vld1q_f32(bp.add(i + 12)));
        i += 16;
    }
    while i + 4 <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i)));
        i += 4;
    }
    let mut s = vaddvq_f32(vaddq_f32(vaddq_f32(acc0, acc1), vaddq_f32(acc2, acc3)));
    while i < n {
        s += *ap.add(i) * *bp.add(i);
        i += 1;
    }
    s
}

/// SQ8 dequant-dot: 8 code bytes per iteration widened
/// u8→u16→u32→f32 (exact conversions), FMA-accumulated in two 4-lane
/// registers.
///
/// # Safety
/// Requires NEON at runtime; `qs.len() == code.len()`.
#[target_feature(enable = "neon")]
pub unsafe fn sq8_dot(qs: &[f32], code: &[u8]) -> f32 {
    debug_assert_eq!(qs.len(), code.len());
    let n = qs.len();
    let qp = qs.as_ptr();
    let cp = code.as_ptr();
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut i = 0usize;
    while i + 8 <= n {
        let wide = vmovl_u8(vld1_u8(cp.add(i)));
        let lo = vcvtq_f32_u32(vmovl_u16(vget_low_u16(wide)));
        let hi = vcvtq_f32_u32(vmovl_u16(vget_high_u16(wide)));
        acc0 = vfmaq_f32(acc0, vld1q_f32(qp.add(i)), lo);
        acc1 = vfmaq_f32(acc1, vld1q_f32(qp.add(i + 4)), hi);
        i += 8;
    }
    let mut s = vaddvq_f32(vaddq_f32(acc0, acc1));
    while i < n {
        s += *qp.add(i) * (*cp.add(i)) as f32;
        i += 1;
    }
    s
}

/// [`super::scalar::not_below_mask`] over one full 4-lane chunk:
/// `!(x < floor)` per lane (NaN lanes kept), packed into bits 0..4.
///
/// # Safety
/// Requires NEON at runtime; `chunk.len() == 4`.
#[target_feature(enable = "neon")]
pub unsafe fn not_below_mask4(chunk: &[f32], floor: f32) -> u32 {
    debug_assert_eq!(chunk.len(), 4);
    let v = vld1q_f32(chunk.as_ptr());
    // vcltq is false for NaN, so the complement keeps NaN lanes — the
    // exact `!(x < floor)` predicate `TopK::offer` uses
    let below = vcltq_f32(v, vdupq_n_f32(floor));
    let keep = vmvnq_u32(below);
    let weights: [u32; 4] = [1, 2, 4, 8];
    let bits = vandq_u32(keep, vld1q_u32(weights.as_ptr()));
    vaddvq_u32(bits)
}
