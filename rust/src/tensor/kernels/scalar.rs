//! Scalar reference kernels — the always-available dispatch tier and
//! the bit-identity anchor for every other tier.
//!
//! `dot` is the exact pre-dispatch kernel body (16-wide blocks, four
//! independent 4-lane accumulators, sequential tail): moving it here
//! changed no instruction order, so the scalar tier scores
//! bit-identically to every artifact and test baseline produced before
//! the dispatch layer existed. The same holds for `sq8_dot` (the old
//! `SqIndex::scaled_score` loop) and `adc_scan8` (the old
//! `Pq::adc_score` loop).

use crate::tensor::half::f16_to_f32;

/// `dot(a, b)` with 4-way unrolled independent accumulators.
///
/// Reduction order (the scalar-tier contract — see
/// `crate::tensor::kernels` for the cross-tier tolerance): the input is
/// cut into 16-element blocks; block `c` accumulates four sequential
/// 4-element partial sums `t0..t3` (lanes `[0..4)`, `[4..8)`, `[8..12)`,
/// `[12..16)`) which are added into four running sums `s0..s3`; the
/// remainder is summed sequentially into `tail`; the result is
/// `s0 + s1 + s2 + s3 + tail` in exactly that order.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 16;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    // 16-wide blocks; LLVM maps each 4-lane accumulator onto vector FMAs.
    for c in 0..chunks {
        let i = c * 16;
        let (a0, b0) = (&a[i..i + 16], &b[i..i + 16]);
        let mut t0 = 0.0f32;
        let mut t1 = 0.0f32;
        let mut t2 = 0.0f32;
        let mut t3 = 0.0f32;
        for j in 0..4 {
            t0 += a0[j] * b0[j];
            t1 += a0[4 + j] * b0[4 + j];
            t2 += a0[8 + j] * b0[8 + j];
            t3 += a0[12 + j] * b0[12 + j];
        }
        s0 += t0;
        s1 += t1;
        s2 += t2;
        s3 += t3;
    }
    let mut tail = 0.0f32;
    for i in chunks * 16..n {
        tail += a[i] * b[i];
    }
    s0 + s1 + s2 + s3 + tail
}

/// Dequantized inner product against f16-stored keys, mirroring the
/// blocked reduction structure of [`dot`] (each `b` element is expanded
/// to f32 before the multiply, which is exact, so the only divergence
/// from an f32 dot is the f16 storage rounding itself).
#[inline]
pub fn dot_f16(a: &[f32], b: &[u16]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 16;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 16;
        let (a0, b0) = (&a[i..i + 16], &b[i..i + 16]);
        let mut t0 = 0.0f32;
        let mut t1 = 0.0f32;
        let mut t2 = 0.0f32;
        let mut t3 = 0.0f32;
        for j in 0..4 {
            t0 += a0[j] * f16_to_f32(b0[j]);
            t1 += a0[4 + j] * f16_to_f32(b0[4 + j]);
            t2 += a0[8 + j] * f16_to_f32(b0[8 + j]);
            t3 += a0[12 + j] * f16_to_f32(b0[12 + j]);
        }
        s0 += t0;
        s1 += t1;
        s2 += t2;
        s3 += t3;
    }
    let mut tail = 0.0f32;
    for i in chunks * 16..n {
        tail += a[i] * f16_to_f32(b[i]);
    }
    s0 + s1 + s2 + s3 + tail
}

/// SQ8 dequant-dot: `Σ qs[j] * code[j]` — the exact sequential loop the
/// pre-dispatch `SqIndex::scaled_score` used (the caller adds the
/// `<query, lo>` constant).
#[inline]
pub fn sq8_dot(qs: &[f32], code: &[u8]) -> f32 {
    let mut s = 0.0f32;
    for (&x, &c) in qs.iter().zip(code) {
        s += x * c as f32;
    }
    s
}

/// 8-bit ADC scan: `Σ_sub table[sub * 256 + code[sub]]` — the exact
/// sequential loop the pre-dispatch `Pq::adc_score` used.
#[inline]
pub fn adc_scan8(table: &[f32], code: &[u8]) -> f32 {
    let mut s = 0.0f32;
    for (sub, &c) in code.iter().enumerate() {
        s += table[sub * 256 + c as usize];
    }
    s
}

/// 4-bit packed ADC scan over an `[m, 16]` table: subspace `2i` lives
/// in the low nibble of byte `i`, subspace `2i+1` in the high nibble.
#[inline]
pub fn adc_scan4(table: &[f32], packed: &[u8], m: usize) -> f32 {
    debug_assert!(packed.len() * 2 >= m);
    let mut s = 0.0f32;
    for sub in 0..m {
        let byte = packed[sub >> 1];
        let nib = if sub & 1 == 0 { byte & 0x0F } else { byte >> 4 };
        s += table[sub * 16 + nib as usize];
    }
    s
}

/// Bitmask of entries NOT strictly below `floor` (bit `i` set iff
/// `!(chunk[i] < floor)`) for a chunk of at most 32 scores. NaN compares
/// false under `<`, so NaN lanes are kept — exactly the set of
/// candidates `TopK::offer` would forward to `push`.
#[inline]
pub fn not_below_mask(chunk: &[f32], floor: f32) -> u32 {
    debug_assert!(chunk.len() <= 32);
    let mut mask = 0u32;
    for (i, &s) in chunk.iter().enumerate() {
        if !(s < floor) {
            mask |= 1 << i;
        }
    }
    mask
}
