//! AVX2+FMA kernel tier (x86-64, runtime-detected). Every function is
//! `#[target_feature]`-gated and only reachable through the dispatch
//! wrappers in `crate::tensor::kernels`, which verify
//! `is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")`
//! before taking this path — that detection is the entire safety
//! argument for the `unsafe` here (plus in-bounds pointer arithmetic,
//! which each loop guards with explicit `i + LANES <= n` bounds).
//!
//! These kernels re-associate the reduction (8-lane FMA accumulators +
//! a horizontal tree sum), so they are *not* bit-identical to the
//! scalar tier; they satisfy the tolerance contract documented in
//! `crate::tensor::kernels`.

#![cfg(target_arch = "x86_64")]

use core::arch::x86_64::*;

use crate::tensor::half::f16_to_f32;

/// Horizontal sum of an 8-lane register (tree reduction).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum256(v: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(v);
    let hi = _mm256_extractf128_ps::<1>(v);
    let s = _mm_add_ps(lo, hi);
    let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_add_ss(s, _mm_shuffle_ps::<0b01>(s, s));
    _mm_cvtss_f32(s)
}

/// 32-wide blocked dot: four 8-lane FMA accumulators, then an 8-wide
/// cleanup loop, a horizontal tree sum, and a sequential scalar tail.
///
/// # Safety
/// Requires AVX2+FMA at runtime; `a.len() == b.len()`.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut acc2 = _mm256_setzero_ps();
    let mut acc3 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 32 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
        acc1 = _mm256_fmadd_ps(
            _mm256_loadu_ps(ap.add(i + 8)),
            _mm256_loadu_ps(bp.add(i + 8)),
            acc1,
        );
        acc2 = _mm256_fmadd_ps(
            _mm256_loadu_ps(ap.add(i + 16)),
            _mm256_loadu_ps(bp.add(i + 16)),
            acc2,
        );
        acc3 = _mm256_fmadd_ps(
            _mm256_loadu_ps(ap.add(i + 24)),
            _mm256_loadu_ps(bp.add(i + 24)),
            acc3,
        );
        i += 32;
    }
    while i + 8 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
        i += 8;
    }
    let mut s = hsum256(_mm256_add_ps(
        _mm256_add_ps(acc0, acc1),
        _mm256_add_ps(acc2, acc3),
    ));
    while i < n {
        s += *ap.add(i) * *bp.add(i);
        i += 1;
    }
    s
}

/// f16 dequant-dot via F16C: 16 halves per iteration expanded with
/// `vcvtph2ps` (exact), then the same FMA accumulation as [`dot`].
///
/// # Safety
/// Requires AVX2+FMA+F16C at runtime; `a.len() == b.len()`.
#[target_feature(enable = "avx2,fma,f16c")]
pub unsafe fn dot_f16(a: &[f32], b: &[u16]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 16 <= n {
        let h = _mm256_loadu_si256(bp.add(i) as *const __m256i);
        let lo = _mm256_cvtph_ps(_mm256_castsi256_si128(h));
        let hi = _mm256_cvtph_ps(_mm256_extracti128_si256::<1>(h));
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), lo, acc0);
        acc1 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i + 8)), hi, acc1);
        i += 16;
    }
    let mut s = hsum256(_mm256_add_ps(acc0, acc1));
    while i < n {
        s += *ap.add(i) * f16_to_f32(*bp.add(i));
        i += 1;
    }
    s
}

/// SQ8 dequant-dot: 16 code bytes widened u8→i32→f32 per iteration
/// (exact conversions), FMA-accumulated in two 8-lane registers.
///
/// # Safety
/// Requires AVX2+FMA at runtime; `qs.len() == code.len()`.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn sq8_dot(qs: &[f32], code: &[u8]) -> f32 {
    debug_assert_eq!(qs.len(), code.len());
    let n = qs.len();
    let qp = qs.as_ptr();
    let cp = code.as_ptr();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 16 <= n {
        let bytes = _mm_loadu_si128(cp.add(i) as *const __m128i);
        let lo = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(bytes));
        let hi = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(_mm_srli_si128::<8>(bytes)));
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(qp.add(i)), lo, acc0);
        acc1 = _mm256_fmadd_ps(_mm256_loadu_ps(qp.add(i + 8)), hi, acc1);
        i += 16;
    }
    let mut s = hsum256(_mm256_add_ps(acc0, acc1));
    while i < n {
        s += *qp.add(i) * (*cp.add(i)) as f32;
        i += 1;
    }
    s
}

/// 8-bit ADC scan: gather 8 table entries per iteration
/// (`vpgatherdps` over indices `sub * 256 + code[sub]`), tree-summed.
///
/// # Safety
/// Requires AVX2 at runtime; `table.len() >= code.len() * 256`.
#[target_feature(enable = "avx2")]
pub unsafe fn adc_scan8(table: &[f32], code: &[u8]) -> f32 {
    let m = code.len();
    debug_assert!(table.len() >= m * 256);
    let tp = table.as_ptr();
    let cp = code.as_ptr();
    let lane = _mm256_setr_epi32(0, 256, 512, 768, 1024, 1280, 1536, 1792);
    let mut acc = _mm256_setzero_ps();
    let mut sub = 0usize;
    while sub + 8 <= m {
        let bytes = _mm_loadl_epi64(cp.add(sub) as *const __m128i);
        let idx = _mm256_add_epi32(_mm256_cvtepu8_epi32(bytes), lane);
        let idx = _mm256_add_epi32(idx, _mm256_set1_epi32((sub * 256) as i32));
        acc = _mm256_add_ps(acc, _mm256_i32gather_ps::<4>(tp, idx));
        sub += 8;
    }
    let mut s = hsum256(acc);
    while sub < m {
        s += *tp.add(sub * 256 + *cp.add(sub) as usize);
        sub += 1;
    }
    s
}

/// 4-bit packed ADC scan over an `[m, 16]` table: 8 subspaces (4 bytes)
/// per iteration — bytes are duplicated into 8 lanes, nibble-shifted
/// with `vpsrlvd`, masked, and gathered.
///
/// # Safety
/// Requires AVX2 at runtime; `packed.len() * 2 >= m` and
/// `table.len() >= m * 16`.
#[target_feature(enable = "avx2")]
pub unsafe fn adc_scan4(table: &[f32], packed: &[u8], m: usize) -> f32 {
    debug_assert!(packed.len() * 2 >= m);
    debug_assert!(table.len() >= m * 16);
    let tp = table.as_ptr();
    let cp = packed.as_ptr();
    let lane = _mm256_setr_epi32(0, 16, 32, 48, 64, 80, 96, 112);
    let shifts = _mm256_setr_epi32(0, 4, 0, 4, 0, 4, 0, 4);
    let dup = _mm_setr_epi8(0, 0, 1, 1, 2, 2, 3, 3, -1, -1, -1, -1, -1, -1, -1, -1);
    let mut acc = _mm256_setzero_ps();
    let mut sub = 0usize;
    while sub + 8 <= m {
        // 4 packed bytes -> lanes [b0,b0,b1,b1,b2,b2,b3,b3]
        let raw = _mm_set1_epi32((cp.add(sub >> 1) as *const i32).read_unaligned());
        let lanes = _mm256_cvtepu8_epi32(_mm_shuffle_epi8(raw, dup));
        let nib = _mm256_and_si256(_mm256_srlv_epi32(lanes, shifts), _mm256_set1_epi32(0xF));
        let idx = _mm256_add_epi32(_mm256_add_epi32(nib, lane), _mm256_set1_epi32((sub * 16) as i32));
        acc = _mm256_add_ps(acc, _mm256_i32gather_ps::<4>(tp, idx));
        sub += 8;
    }
    let mut s = hsum256(acc);
    while sub < m {
        let byte = *cp.add(sub >> 1);
        let nib = if sub & 1 == 0 { byte & 0x0F } else { byte >> 4 };
        s += *tp.add(sub * 16 + nib as usize);
        sub += 1;
    }
    s
}

/// [`super::scalar::not_below_mask`] over one full 8-lane chunk:
/// `_CMP_NLT_UQ` is exactly `!(x < floor)` (true for NaN lanes).
///
/// # Safety
/// Requires AVX2 at runtime; `chunk.len() == 8`.
#[target_feature(enable = "avx2")]
pub unsafe fn not_below_mask8(chunk: &[f32], floor: f32) -> u32 {
    debug_assert_eq!(chunk.len(), 8);
    let v = _mm256_loadu_ps(chunk.as_ptr());
    let m = _mm256_cmp_ps::<_CMP_NLT_UQ>(v, _mm256_set1_ps(floor));
    _mm256_movemask_ps(m) as u32
}
