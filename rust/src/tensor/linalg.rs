//! Hot-path linear algebra: blocked `A·Bᵀ` (the MIPS scoring primitive),
//! dot products, row normalization, and a power-iteration PCA used by the
//! LeanVec-like index and the Fig. 29 diagnostics.
//!
//! The inner-product kernel dispatches through
//! [`crate::tensor::kernels`] (AVX2+FMA / NEON when the CPU has them,
//! the scalar reference tier otherwise or under `AMIPS_FORCE_SCALAR=1`);
//! everything else here is straight-line f32 code with independent
//! accumulators that LLVM autovectorizes.

use crate::tensor::kernels;
use crate::tensor::Tensor;
use crate::util::threads::parallel_rows_mut;

/// `dot(a, b)`, dispatched through [`crate::tensor::kernels`].
///
/// Reduction-order contract: the *scalar tier* result is pinned to the
/// documented block order of [`kernels::scalar::dot`] (16-element
/// blocks, four sequential 4-lane partials, `s0+s1+s2+s3+tail`) and is
/// bit-identical to this function's pre-dispatch behavior. SIMD tiers
/// re-associate the sum and agree with scalar only within the tolerance
/// contract documented in [`crate::tensor::kernels`]. Within one
/// process the tier is fixed, so any two `dot` calls on the same inputs
/// are bit-identical to each other — which is what the batched ≡
/// per-query contract relies on.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    kernels::dot(a, b)
}

/// `y += alpha * x`.
#[inline]
pub fn scaled_add(y: &mut [f32], x: &[f32], alpha: f32) {
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// out[i, j] = <a_i, b_j>   for a [m,d], b [n,d]  (i.e. A·Bᵀ, the MIPS
/// scoring matrix). Parallel over rows of `a`; inner loop blocked over
/// `b` rows so a tile of B stays in L1/L2.
pub fn gemm_nt(a: &Tensor, b: &Tensor, out: &mut Tensor) {
    let (m, d) = (a.rows(), a.row_width());
    let (n, db) = (b.rows(), b.row_width());
    assert_eq!(d, db, "dim mismatch {d} vs {db}");
    assert_eq!(out.shape(), &[m, n]);
    let bd = b.data();
    let ad = a.data();
    const BN: usize = 64; // B-row tile: 64 rows * 64 dims * 4B = 16 KB (L1)
    parallel_rows_mut(out.data_mut(), n, 16, |r0, r1, chunk| {
        for (local, row_out) in chunk.chunks_mut(n).enumerate() {
            let i = r0 + local;
            debug_assert!(i < r1);
            let ai = &ad[i * d..(i + 1) * d];
            let mut j0 = 0;
            while j0 < n {
                let j1 = (j0 + BN).min(n);
                for j in j0..j1 {
                    row_out[j] = dot(ai, &bd[j * d..(j + 1) * d]);
                }
                j0 = j1;
            }
        }
    });
}

/// Sequential `A·Bᵀ` tile kernel over raw row-major storage:
/// `out[i*bn + j] = dot(&a[i*d..], &b[j*d..])` for `a` [m,d], `b`
/// [bn,d]. This is the building block of the fused batched scans in
/// `crate::index` (query-tile × key-tile, batch × centroids, batch ×
/// codewords): callers own tiling and parallelism, so the kernel never
/// spawns threads and can run inside pool workers. It scores through
/// the same [`dot`] as every per-query scan loop, so fused results are
/// bit-identical to per-query ones.
pub fn gemm_nt_tile(a: &[f32], b: &[f32], d: usize, out: &mut [f32]) {
    assert!(d > 0, "gemm_nt_tile needs d > 0");
    assert_eq!(a.len() % d, 0, "a len {} not a multiple of d={d}", a.len());
    assert_eq!(b.len() % d, 0, "b len {} not a multiple of d={d}", b.len());
    let m = a.len() / d;
    let bn = b.len() / d;
    assert_eq!(out.len(), m * bn, "out len {} != {m}x{bn}", out.len());
    for (i, row_out) in out.chunks_mut(bn.max(1)).enumerate().take(m) {
        let ai = &a[i * d..(i + 1) * d];
        for (j, o) in row_out.iter_mut().enumerate() {
            *o = dot(ai, &b[j * d..(j + 1) * d]);
        }
    }
}

/// y = M x for M [m,d] (rows), x [d].
pub fn matvec(m_rows: usize, d: usize, m: &[f32], x: &[f32], y: &mut [f32]) {
    assert_eq!(m.len(), m_rows * d);
    assert_eq!(x.len(), d);
    assert_eq!(y.len(), m_rows);
    for i in 0..m_rows {
        y[i] = dot(&m[i * d..(i + 1) * d], x);
    }
}

/// L2-normalize every row in place; zero rows are left untouched.
pub fn normalize_rows(t: &mut Tensor) {
    let w = t.row_width();
    for row in t.data_mut().chunks_mut(w) {
        let nrm = dot(row, row).sqrt();
        if nrm > 1e-12 {
            let inv = 1.0 / nrm;
            for v in row {
                *v *= inv;
            }
        }
    }
}

/// Top-`k` principal components of the rows of `x` (mean-centered),
/// via block power iteration with Gram–Schmidt re-orthonormalization.
/// Returns (components [k,d], mean [d]).
pub fn power_iteration_pca(x: &Tensor, k: usize, iters: usize, seed: u64) -> (Tensor, Vec<f32>) {
    let (n, d) = (x.rows(), x.row_width());
    assert!(k <= d && n > 0);
    let mut mean = vec![0.0f32; d];
    for i in 0..n {
        scaled_add(&mut mean, x.row(i), 1.0 / n as f32);
    }
    let mut rng = crate::util::Rng::new(seed ^ 0x9E37);
    let mut comps = Tensor::zeros(&[k, d]);
    rng.fill_normal(comps.data_mut(), 1.0);
    let mut proj = vec![0.0f32; n];
    for _ in 0..iters {
        for c in 0..k {
            // proj = (X - mean) v_c ; v_c <- (X - mean)^T proj
            // One matvec for X·v_c (the kernel's unrolled dot beats the
            // old per-row loop) and <mean, v_c> hoisted out: dot(x_i, v)
            // - dot(mean, v) computes the exact same subtraction either
            // way, so results are unchanged.
            {
                let v = comps.row(c);
                let mv = dot(&mean, v);
                matvec(n, d, x.data(), v, &mut proj);
                for p in proj.iter_mut() {
                    *p -= mv;
                }
            }
            let mut newv = vec![0.0f32; d];
            for i in 0..n {
                scaled_add(&mut newv, x.row(i), proj[i]);
            }
            let psum: f32 = proj.iter().sum();
            scaled_add(&mut newv, &mean, -psum);
            // Gram–Schmidt against previous components.
            for p in 0..c {
                let coef = dot(&newv, comps.row(p));
                let prev = comps.row(p).to_vec();
                scaled_add(&mut newv, &prev, -coef);
            }
            let nrm = dot(&newv, &newv).sqrt().max(1e-12);
            for v in &mut newv {
                *v /= nrm;
            }
            comps.row_mut(c).copy_from_slice(&newv);
        }
    }
    (comps, mean)
}

/// Project rows of `x` onto PCA components: out[i,c] = <x_i - mean, comp_c>.
/// One blocked [`gemm_nt`] for X·Cᵀ plus a hoisted <mean, comp_c> row —
/// same `dot` calls and the same subtraction as the old per-row loops,
/// so projections are bit-identical, just tiled (and parallel at build
/// time).
pub fn pca_project(x: &Tensor, comps: &Tensor, mean: &[f32]) -> Tensor {
    let (n, d) = (x.rows(), x.row_width());
    let k = comps.rows();
    assert_eq!(comps.row_width(), d);
    assert_eq!(mean.len(), d);
    let mut out = Tensor::zeros(&[n, k]);
    if n == 0 || k == 0 {
        return out;
    }
    gemm_nt(x, comps, &mut out);
    let mean_dots: Vec<f32> = (0..k).map(|c| dot(mean, comps.row(c))).collect();
    for i in 0..n {
        for (o, md) in out.row_mut(i).iter_mut().zip(&mean_dots) {
            *o -= md;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        Rng::new(seed).fill_normal(t.data_mut(), 1.0);
        t
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::new(1);
        for n in [0, 1, 15, 16, 17, 64, 100] {
            let mut a = vec![0.0; n];
            let mut b = vec![0.0; n];
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut b, 1.0);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-3, "n={n}");
        }
    }

    #[test]
    fn gemm_nt_matches_naive() {
        let a = randt(&[7, 33], 2);
        let b = randt(&[9, 33], 3);
        let mut out = Tensor::zeros(&[7, 9]);
        gemm_nt(&a, &b, &mut out);
        for i in 0..7 {
            for j in 0..9 {
                let naive: f32 = a.row(i).iter().zip(b.row(j)).map(|(x, y)| x * y).sum();
                assert!((out.row(i)[j] - naive).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn gemm_nt_tile_matches_gemm_nt_bitwise() {
        // the sequential tile kernel must agree with the blocked parallel
        // gemm exactly — both route every score through `dot`
        let a = randt(&[5, 24], 8);
        let b = randt(&[11, 24], 9);
        let mut full = Tensor::zeros(&[5, 11]);
        gemm_nt(&a, &b, &mut full);
        let mut tile = vec![0.0f32; 5 * 11];
        gemm_nt_tile(a.data(), b.data(), 24, &mut tile);
        assert_eq!(full.data(), &tile[..]);
        // degenerate: empty b tile
        gemm_nt_tile(a.data(), &[], 24, &mut []);
    }

    #[test]
    fn pca_project_matches_per_row_dots_bitwise() {
        // the gemm-based projection must equal the old per-row loop
        // exactly: same dot calls, same subtraction
        let x = randt(&[40, 12], 10);
        let (comps, mean) = power_iteration_pca(&x, 3, 10, 3);
        let p = pca_project(&x, &comps, &mean);
        for i in 0..40 {
            for c in 0..3 {
                let v = comps.row(c);
                let want = dot(x.row(i), v) - dot(&mean, v);
                assert_eq!(p.row(i)[c], want, "({i},{c})");
            }
        }
    }

    #[test]
    fn normalize_rows_unit() {
        let mut t = randt(&[5, 16], 4);
        normalize_rows(&mut t);
        for i in 0..5 {
            let n = dot(t.row(i), t.row(i)).sqrt();
            assert!((n - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn normalize_rows_zero_safe() {
        let mut t = Tensor::zeros(&[2, 4]);
        normalize_rows(&mut t);
        assert!(t.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn pca_finds_dominant_direction() {
        // Points spread along a known axis + small noise.
        let d = 8;
        let n = 400;
        let mut rng = Rng::new(5);
        let mut axis = vec![0.0f32; d];
        axis[2] = 1.0;
        let mut x = Tensor::zeros(&[n, d]);
        for i in 0..n {
            let t = rng.normal() as f32 * 5.0;
            let row = x.row_mut(i);
            for (j, r) in row.iter_mut().enumerate() {
                *r = axis[j] * t + rng.normal() as f32 * 0.05;
            }
        }
        let (comps, _mean) = power_iteration_pca(&x, 1, 30, 0);
        let c = comps.row(0);
        assert!(c[2].abs() > 0.99, "pc0 = {c:?}");
    }

    #[test]
    fn pca_components_orthonormal() {
        let x = randt(&[200, 16], 6);
        let (comps, _) = power_iteration_pca(&x, 3, 25, 1);
        for i in 0..3 {
            for j in 0..3 {
                let d = dot(comps.row(i), comps.row(j));
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-3, "({i},{j}) -> {d}");
            }
        }
    }

    #[test]
    fn pca_project_shapes() {
        let x = randt(&[10, 6], 7);
        let (comps, mean) = power_iteration_pca(&x, 2, 10, 2);
        let p = pca_project(&x, &comps, &mean);
        assert_eq!(p.shape(), &[10, 2]);
    }
}
