//! Dense row-major f32 tensors + the linear algebra the substrates need.
//!
//! The hot scoring loops route through [`kernels`], a runtime-dispatched
//! layer with AVX2+FMA / NEON tiers and a bit-identical scalar fallback
//! (`AMIPS_FORCE_SCALAR=1` pins it). [`half`] is the binary16 codec
//! behind the compact `storage=f16` key matrices.

pub mod half;
pub mod kernels;
mod linalg;
pub mod mapped;
#[allow(clippy::module_inception)]
mod tensor;

pub use linalg::{
    dot, gemm_nt, gemm_nt_tile, matvec, normalize_rows, pca_project, power_iteration_pca,
    scaled_add,
};
pub use mapped::{Mapped, Section};
pub use tensor::{load_tensor_set, save_tensor_set, Tensor};
