//! Dense row-major f32 tensors + the linear algebra the substrates need.

mod linalg;
#[allow(clippy::module_inception)]
mod tensor;

pub use linalg::{
    dot, gemm_nt, gemm_nt_tile, matvec, normalize_rows, pca_project, power_iteration_pca,
    scaled_add,
};
pub use tensor::{load_tensor_set, save_tensor_set, Tensor};
