//! Minimal IEEE 754 binary16 ("f16") codec — no external crate, just the
//! bit manipulation. Used by the compact `storage=f16` key matrices
//! (see `crate::index::keystore`): keys are stored as 16-bit patterns
//! and dequantized to f32 inside the scoring kernels, halving scan-path
//! memory bandwidth.
//!
//! Conversion contract:
//! - `f16_from_f32` rounds to nearest-even, overflows to ±inf, flushes
//!   sub-2⁻²⁵ magnitudes to signed zero, and maps every NaN to a quiet
//!   NaN (payload not preserved).
//! - `f16_to_f32` is exact (every binary16 value is representable in
//!   f32), so `f16_from_f32(f16_to_f32(h)) == h` for every non-NaN bit
//!   pattern `h` — tested exhaustively over all 2¹⁶ patterns below.

/// Convert an `f32` to the nearest binary16 bit pattern
/// (round-to-nearest-even).
pub fn f16_from_f32(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // inf stays inf; NaN becomes a quiet NaN (mantissa must stay
        // non-zero or the NaN would silently turn into inf)
        return sign | 0x7C00 | if man != 0 { 0x0200 } else { 0 };
    }
    let e = exp - 127;
    if e >= 16 {
        return sign | 0x7C00; // overflow -> inf
    }
    if e >= -14 {
        // normal binary16: narrow the mantissa 23 -> 10 bits with RNE;
        // a mantissa carry overflows into the exponent, which is still
        // the correctly rounded result (next binade, or inf at the top)
        let man16 = man >> 13;
        let rem = man & 0x1FFF;
        let mut h = sign as u32 | (((e + 15) as u32) << 10) | man16;
        if rem > 0x1000 || (rem == 0x1000 && (man16 & 1) == 1) {
            h += 1;
        }
        return h as u16;
    }
    if e >= -25 {
        // subnormal binary16: shift the (implicit-1) mantissa into place
        let man = man | 0x0080_0000;
        let shift = (-14 - e) as u32 + 13; // 14..=24
        let man16 = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut h = sign as u32 | man16;
        if rem > half || (rem == half && (man16 & 1) == 1) {
            h += 1;
        }
        return h as u16;
    }
    sign // underflow to signed zero
}

/// Convert a binary16 bit pattern to the `f32` it denotes (exact).
#[inline]
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = (h >> 10) & 0x1F;
    let man = (h & 0x03FF) as u32;
    match exp {
        0 => {
            // subnormal (or zero): value = man * 2^-24, exact in f32
            // (man <= 1023 and the scale is a power of two)
            let mag = man as f32 * f32::from_bits(0x3380_0000);
            f32::from_bits(mag.to_bits() | sign)
        }
        0x1F => f32::from_bits(sign | 0x7F80_0000 | (man << 13)),
        e => f32::from_bits(sign | ((e as u32 + 112) << 23) | (man << 13)),
    }
}

/// Encode a whole f32 slice to binary16 bit patterns.
pub fn encode_f16(src: &[f32]) -> Vec<u16> {
    src.iter().map(|&x| f16_from_f32(x)).collect()
}

/// Decode a binary16 slice back to f32.
pub fn decode_f16(src: &[u16]) -> Vec<f32> {
    src.iter().map(|&h| f16_to_f32(h)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specials_round_trip() {
        assert_eq!(f16_from_f32(0.0), 0x0000);
        assert_eq!(f16_from_f32(-0.0), 0x8000);
        assert_eq!(f16_from_f32(1.0), 0x3C00);
        assert_eq!(f16_from_f32(-2.0), 0xC000);
        assert_eq!(f16_from_f32(65504.0), 0x7BFF); // f16::MAX
        assert_eq!(f16_from_f32(f32::INFINITY), 0x7C00);
        assert_eq!(f16_from_f32(f32::NEG_INFINITY), 0xFC00);
        assert!(f16_to_f32(f16_from_f32(f32::NAN)).is_nan());
        assert_eq!(f16_to_f32(0x3C00), 1.0);
        assert_eq!(f16_to_f32(0x0001), f32::from_bits(0x3380_0000)); // 2^-24
        assert_eq!(f16_to_f32(0x8000), 0.0);
        assert!(f16_to_f32(0x8000).is_sign_negative());
    }

    #[test]
    fn overflow_and_underflow() {
        assert_eq!(f16_from_f32(1e9), 0x7C00); // -> +inf
        assert_eq!(f16_from_f32(-1e9), 0xFC00);
        assert_eq!(f16_from_f32(1e-10), 0x0000); // -> +0
        assert_eq!(f16_from_f32(-1e-10), 0x8000); // -> -0
        // 65520 is the RNE midpoint between f16::MAX and the (absent)
        // next binade: rounds up to inf
        assert_eq!(f16_from_f32(65520.0), 0x7C00);
        assert_eq!(f16_from_f32(65519.9), 0x7BFF);
    }

    #[test]
    fn rounds_to_nearest_even() {
        // 1 + 2^-11 is exactly between 1.0 and the next f16 (1 + 2^-10):
        // ties to even -> 1.0
        assert_eq!(f16_from_f32(1.0 + 2f32.powi(-11)), 0x3C00);
        // nudge above the midpoint -> rounds up
        assert_eq!(f16_from_f32(1.0 + 2f32.powi(-11) + 2f32.powi(-20)), 0x3C01);
        // 1 + 3*2^-11 ties between 0x3C01 and 0x3C02 -> even (0x3C02)
        assert_eq!(f16_from_f32(1.0 + 3.0 * 2f32.powi(-11)), 0x3C02);
    }

    #[test]
    fn exhaustive_bit_pattern_round_trip() {
        // every non-NaN binary16 value is exact in f32 and must survive
        // the round trip bit-for-bit (NaNs collapse to the quiet NaN)
        for h in 0..=u16::MAX {
            let is_nan = (h >> 10) & 0x1F == 0x1F && h & 0x03FF != 0;
            let f = f16_to_f32(h);
            if is_nan {
                assert!(f.is_nan(), "{h:#06x}");
            } else {
                assert_eq!(f16_from_f32(f), h, "{h:#06x} -> {f}");
            }
        }
    }

    #[test]
    fn relative_error_within_half_ulp() {
        // binary16 has 11 significand bits: RNE keeps relative error
        // <= 2^-11 for normal-range values
        let mut x = 1.0e-4f32;
        while x < 6.0e4 {
            let back = f16_to_f32(f16_from_f32(x));
            let rel = ((back - x) / x).abs();
            assert!(rel <= 4.9e-4, "x={x} back={back} rel={rel}");
            x *= 1.37;
        }
    }

    #[test]
    fn slice_helpers_round_trip() {
        let src = [0.0f32, 1.5, -3.25, 1e-5, 1e5];
        let enc = encode_f16(&src);
        let dec = decode_f16(&enc);
        assert_eq!(dec.len(), 5);
        assert_eq!(dec[0], 0.0);
        assert_eq!(dec[1], 1.5);
        assert_eq!(dec[2], -3.25);
    }
}
