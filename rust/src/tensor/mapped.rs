//! Byte sources and zero-copy sections for the on-disk containers.
//!
//! [`Mapped`] is the byte source: `mmap(2)` under the `mmap` feature
//! (zero-copy page-cache startup), plain `std::fs::read` into RAM
//! otherwise. No new crates — the mmap path is a three-symbol libc FFI
//! that std already links against on unix.
//!
//! [`Section<T>`] is the zero-copy unit built on top of it: a typed
//! slice that either owns a `Vec<T>` (the decode-into-RAM path every
//! pre-v3 container uses) or borrows a range of a shared [`Mapped`]
//! region, holding the mapping alive via `Arc<Mapped>`. The borrowed
//! arm is only constructible through the checked [`Section::view`]
//! accessor, which verifies the *runtime address* alignment (mmap is
//! page-aligned but a `Vec` fallback need not be), bounds, and target
//! endianness before casting — callers fall back to a copy when it
//! returns `None`, never to UB.
//!
//! The [`stats`] counters record how many payload bytes were served
//! borrowed vs. copied; the metrics listener exports them as
//! `amips_mapped_bytes` / `amips_copied_bytes`.

use std::fs::File;
use std::io::{self, Read};
use std::path::Path;
use std::sync::Arc;

/// Global mapped-vs-copied byte counters (process-wide, monotonic).
pub mod stats {
    use std::sync::atomic::{AtomicU64, Ordering};

    static MAPPED: AtomicU64 = AtomicU64::new(0);
    static COPIED: AtomicU64 = AtomicU64::new(0);

    /// Record `bytes` served as a borrowed view of a mapping.
    pub fn add_mapped(bytes: u64) {
        MAPPED.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Record `bytes` decoded into a fresh RAM copy.
    pub fn add_copied(bytes: u64) {
        COPIED.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn mapped_bytes() -> u64 {
        MAPPED.load(Ordering::Relaxed)
    }

    pub fn copied_bytes() -> u64 {
        COPIED.load(Ordering::Relaxed)
    }
}

/// An immutable byte buffer backed either by an anonymous read of the
/// file or (with `--features mmap` on unix) by a private read-only
/// mapping. Deref to `&[u8]` and hand it to a container decoder.
pub struct Mapped {
    inner: Inner,
}

enum Inner {
    Ram(Vec<u8>),
    #[cfg(all(feature = "mmap", unix))]
    Map(map::MapHandle),
}

impl Mapped {
    /// Read (or map) an entire file. Empty files yield an empty slice
    /// through the RAM path: `mmap` with `len == 0` is EINVAL.
    pub fn open(path: &Path) -> io::Result<Mapped> {
        let mut f = File::open(path)?;
        let len = f.metadata()?.len();
        if len > usize::MAX as u64 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "segment file larger than address space",
            ));
        }
        #[cfg(all(feature = "mmap", unix))]
        {
            if len > 0 {
                match map::MapHandle::map(&f, len as usize) {
                    Ok(m) => return Ok(Mapped { inner: Inner::Map(m) }),
                    // e.g. a filesystem that refuses mappings — fall
                    // back to the portable read-into-RAM path.
                    Err(_) => {}
                }
            }
        }
        let mut buf = Vec::with_capacity(len as usize);
        f.read_to_end(&mut buf)?;
        Ok(Mapped { inner: Inner::Ram(buf) })
    }

    /// Wrap an in-RAM buffer (used by tests and by writers that keep
    /// the bytes they just produced).
    pub fn from_vec(buf: Vec<u8>) -> Mapped {
        Mapped { inner: Inner::Ram(buf) }
    }

    /// Whether this buffer is a real file mapping (page-cache backed)
    /// rather than an anonymous RAM copy. Lazy opens skip the
    /// full-payload checksum only for real mappings — verifying it
    /// would fault in every page and defeat the O(1) open.
    pub fn is_map(&self) -> bool {
        match &self.inner {
            Inner::Ram(_) => false,
            #[cfg(all(feature = "mmap", unix))]
            Inner::Map(_) => true,
        }
    }

    /// `madvise(MADV_SEQUENTIAL)` on `[off, off + len)` of a real
    /// mapping — a scan-section hint, ignored on RAM buffers and on
    /// non-mmap builds. Advisory only: errors are discarded.
    pub fn advise_sequential(&self, off: usize, len: usize) {
        let _ = (off, len);
        match &self.inner {
            Inner::Ram(_) => {}
            #[cfg(all(feature = "mmap", unix))]
            Inner::Map(m) => m.advise_sequential(off, len),
        }
    }

    pub fn as_slice(&self) -> &[u8] {
        match &self.inner {
            Inner::Ram(v) => v,
            #[cfg(all(feature = "mmap", unix))]
            Inner::Map(m) => m.as_slice(),
        }
    }

    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl std::ops::Deref for Mapped {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

/// Element types a [`Section`] may view in place.
///
/// # Safety
///
/// Implementors assert that every bit pattern is a valid value and the
/// type has no padding — the borrowed arm casts raw little-endian file
/// bytes to `&[Self]` after an address-alignment check.
pub unsafe trait Pod: Copy + Send + Sync + 'static {
    /// Decode one element from exactly `size_of::<Self>()` LE bytes
    /// (the copy fallback for misaligned or big-endian hosts).
    fn from_le_bytes(b: &[u8]) -> Self;
}

unsafe impl Pod for u8 {
    fn from_le_bytes(b: &[u8]) -> u8 {
        b[0]
    }
}

unsafe impl Pod for u16 {
    fn from_le_bytes(b: &[u8]) -> u16 {
        u16::from_le_bytes([b[0], b[1]])
    }
}

unsafe impl Pod for u32 {
    fn from_le_bytes(b: &[u8]) -> u32 {
        u32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

unsafe impl Pod for f32 {
    fn from_le_bytes(b: &[u8]) -> f32 {
        f32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

/// A typed slice that is either owned (decoded into RAM) or a borrowed
/// view of a shared [`Mapped`] region. Deref to `&[T]`, so call sites
/// index it exactly like the `Vec<T>` it replaces; mutation goes
/// through [`Section::make_owned`] (copy-on-write).
pub enum Section<T: Pod> {
    Owned(Vec<T>),
    View {
        map: Arc<Mapped>,
        /// Byte offset of the first element within the mapping.
        off: usize,
        /// Element (not byte) count.
        len: usize,
    },
}

impl<T: Pod> Section<T> {
    pub fn owned(v: Vec<T>) -> Section<T> {
        Section::Owned(v)
    }

    /// Decode `raw` (little-endian, `len * size_of::<T>()` bytes) into
    /// an owned section — the universal fallback path.
    pub fn from_le_bytes(raw: &[u8]) -> Section<T> {
        let sz = std::mem::size_of::<T>();
        debug_assert_eq!(raw.len() % sz, 0);
        Section::Owned(raw.chunks_exact(sz).map(T::from_le_bytes).collect())
    }

    /// The checked-alignment accessor: a borrowed view of `len`
    /// elements starting `off` bytes into `map`, or `None` when the
    /// cast would be unsound — range out of bounds, the *runtime
    /// address* `map + off` not aligned for `T` (mmap is page-aligned
    /// but in-file section offsets and `Vec` fallbacks need not be), or
    /// a big-endian host (file bytes are LE). Callers treat `None` as
    /// "copy instead", never as an error.
    pub fn view(map: &Arc<Mapped>, off: usize, len: usize) -> Option<Section<T>> {
        if cfg!(target_endian = "big") {
            return None;
        }
        let bytes = len.checked_mul(std::mem::size_of::<T>())?;
        let end = off.checked_add(bytes)?;
        if end > map.len() {
            return None;
        }
        let addr = map.as_slice().as_ptr() as usize + off;
        if addr % std::mem::align_of::<T>() != 0 {
            return None;
        }
        stats::add_mapped(bytes as u64);
        Some(Section::View {
            map: Arc::clone(map),
            off,
            len,
        })
    }

    pub fn as_slice(&self) -> &[T] {
        match self {
            Section::Owned(v) => v,
            Section::View { map, off, len } => unsafe {
                // bounds + alignment + endianness were verified by
                // `view`; the Arc keeps the mapping alive for &self.
                std::slice::from_raw_parts(
                    map.as_slice().as_ptr().add(*off) as *const T,
                    *len,
                )
            },
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Section::Owned(v) => v.len(),
            Section::View { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_view(&self) -> bool {
        matches!(self, Section::View { .. })
    }

    /// Copy-on-write: replace a view with an owned copy (no-op when
    /// already owned) and return the vector for mutation.
    pub fn make_owned(&mut self) -> &mut Vec<T> {
        if self.is_view() {
            let v = self.as_slice().to_vec();
            *self = Section::Owned(v);
        }
        match self {
            Section::Owned(v) => v,
            Section::View { .. } => unreachable!("make_owned replaced the view"),
        }
    }

    pub fn to_vec(&self) -> Vec<T> {
        self.as_slice().to_vec()
    }

    pub fn into_vec(self) -> Vec<T> {
        match self {
            Section::Owned(v) => v,
            view => view.as_slice().to_vec(),
        }
    }

    /// Pass the sequential-scan hint through to the backing mapping
    /// (no-op for owned sections).
    pub fn advise_sequential(&self) {
        if let Section::View { map, off, len } = self {
            map.advise_sequential(*off, len * std::mem::size_of::<T>());
        }
    }
}

impl<T: Pod> std::ops::Deref for Section<T> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Pod> Clone for Section<T> {
    fn clone(&self) -> Section<T> {
        match self {
            Section::Owned(v) => Section::Owned(v.clone()),
            Section::View { map, off, len } => Section::View {
                map: Arc::clone(map),
                off: *off,
                len: *len,
            },
        }
    }
}

impl<T: Pod + PartialEq> PartialEq for Section<T> {
    fn eq(&self, other: &Section<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Pod> std::fmt::Debug for Section<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Section")
            .field("len", &self.len())
            .field("view", &self.is_view())
            .finish()
    }
}

impl<T: Pod> From<Vec<T>> for Section<T> {
    fn from(v: Vec<T>) -> Section<T> {
        Section::Owned(v)
    }
}

#[cfg(all(feature = "mmap", unix))]
mod map {
    use std::fs::File;
    use std::io;
    use std::os::unix::io::AsRawFd;

    use core::ffi::{c_int, c_void};

    const PROT_READ: c_int = 1;
    const MAP_PRIVATE: c_int = 2;
    const MADV_SEQUENTIAL: c_int = 2;
    /// Conservative page size for rounding `madvise` ranges: real page
    /// sizes are multiples of 4 KiB on every unix we target, and a
    /// misrounded hint is merely ignored.
    const PAGE: usize = 4096;

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> c_int;
        fn madvise(addr: *mut c_void, len: usize, advice: c_int) -> c_int;
    }

    /// A private read-only mapping of one whole file, unmapped on drop.
    pub(super) struct MapHandle {
        ptr: *mut c_void,
        len: usize,
    }

    // The mapping is read-only and owned exclusively by this handle.
    unsafe impl Send for MapHandle {}
    unsafe impl Sync for MapHandle {}

    impl MapHandle {
        pub(super) fn map(f: &File, len: usize) -> io::Result<MapHandle> {
            let ptr = unsafe {
                mmap(
                    std::ptr::null_mut(),
                    len,
                    PROT_READ,
                    MAP_PRIVATE,
                    f.as_raw_fd(),
                    0,
                )
            };
            // MAP_FAILED is (void*)-1 on every unix we target.
            if ptr as isize == -1 || ptr.is_null() {
                return Err(io::Error::last_os_error());
            }
            Ok(MapHandle { ptr, len })
        }

        pub(super) fn as_slice(&self) -> &[u8] {
            unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
        }

        pub(super) fn advise_sequential(&self, off: usize, len: usize) {
            let start = off & !(PAGE - 1);
            let end = off.saturating_add(len).min(self.len);
            if start >= end {
                return;
            }
            unsafe {
                madvise(
                    (self.ptr as *mut u8).add(start) as *mut c_void,
                    end - start,
                    MADV_SEQUENTIAL,
                );
            }
        }
    }

    impl Drop for MapHandle {
        fn drop(&mut self) {
            unsafe {
                munmap(self.ptr, self.len);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;

    #[test]
    fn open_reads_whole_file() {
        let tmp = TempDir::new("mapped");
        let path = tmp.join("blob.bin");
        let bytes: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        std::fs::write(&path, &bytes).unwrap();
        let m = Mapped::open(&path).unwrap();
        assert_eq!(&m[..], &bytes[..]);
        assert_eq!(m.len(), 10_000);
    }

    #[test]
    fn open_empty_file_is_empty_slice() {
        let tmp = TempDir::new("mapped");
        let path = tmp.join("empty.bin");
        std::fs::write(&path, b"").unwrap();
        let m = Mapped::open(&path).unwrap();
        assert!(m.is_empty());
    }

    #[test]
    fn missing_file_is_io_error() {
        let tmp = TempDir::new("mapped");
        assert!(Mapped::open(&tmp.join("nope.bin")).is_err());
    }

    #[test]
    fn view_rejects_misaligned_frames() {
        let m = Arc::new(Mapped::from_vec((0..128).map(|i| i as u8).collect()));
        let base = m.as_slice().as_ptr() as usize;
        // an offset whose *runtime address* is ≡ 1 (mod 4): never
        // f32-aligned regardless of where the allocator placed the Vec
        let mis = (4 - (base % 4)) % 4 + 1;
        assert!(Section::<f32>::view(&m, mis, 4).is_none());
        assert!(Section::<u32>::view(&m, mis, 4).is_none());
        // u8 views have no alignment requirement
        assert!(Section::<u8>::view(&m, mis, 4).is_some());
    }

    #[test]
    fn view_checks_bounds() {
        let m = Arc::new(Mapped::from_vec(vec![0u8; 64]));
        assert!(Section::<u8>::view(&m, 0, 65).is_none());
        assert!(Section::<u8>::view(&m, 60, 5).is_none());
        assert!(Section::<f32>::view(&m, 0, 17).is_none());
        assert!(Section::<u8>::view(&m, usize::MAX, 2).is_none());
    }

    #[cfg(target_endian = "little")]
    #[test]
    fn aligned_view_reads_in_place_and_copies_on_write() {
        let vals = [1.5f32, -2.25, 3.0, 0.125];
        let mut bytes = vec![0u8; 16];
        for (c, v) in bytes.chunks_exact_mut(4).zip(vals) {
            c.copy_from_slice(&v.to_le_bytes());
        }
        let m = Arc::new(Mapped::from_vec(bytes));
        let base = m.as_slice().as_ptr() as usize;
        if base % 4 != 0 {
            // allocator placed the Vec unaligned (legal, just rare):
            // the accessor must refuse, which IS the contract
            assert!(Section::<f32>::view(&m, 0, 4).is_none());
            return;
        }
        let mut s = Section::<f32>::view(&m, 0, 4).unwrap();
        assert!(s.is_view());
        assert_eq!(&s[..], &vals[..]);
        // bit-identical to the decode-and-copy path
        assert_eq!(
            Section::<f32>::from_le_bytes(m.as_slice()).as_slice(),
            s.as_slice()
        );
        s.make_owned()[0] = 9.0;
        assert!(!s.is_view());
        assert_eq!(s[0], 9.0);
        // the mapping is untouched
        assert_eq!(m.as_slice()[0..4], 1.5f32.to_le_bytes());
    }

    #[test]
    fn stats_counters_are_monotonic() {
        let before = stats::copied_bytes();
        stats::add_copied(16);
        assert!(stats::copied_bytes() >= before + 16);
        let before = stats::mapped_bytes();
        stats::add_mapped(8);
        assert!(stats::mapped_bytes() >= before + 8);
    }
}
