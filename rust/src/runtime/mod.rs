//! PJRT runtime: loads the HLO-text artifacts produced by `make
//! artifacts` and executes them on the CPU PJRT client. This is the only
//! boundary between L3 (Rust) and the AOT-compiled L1/L2 stack.

pub mod artifact;
pub mod engine;

pub use artifact::{ArtifactMeta, Manifest};
pub use engine::{lit_f32, lit_scalar_u32, literal_to_vec, Engine, Executable};
