//! Artifact metadata (always available) and the PJRT runtime (behind the
//! `xla` feature): the only boundary between L3 (Rust) and the
//! AOT-compiled L1/L2 stack. `make artifacts` writes HLO-text artifacts
//! plus line-oriented metadata sidecars; the metadata parser is pure Rust
//! so manifests, dataset specs and checkpoints work without XLA.

pub mod artifact;
#[cfg(feature = "xla")]
pub mod engine;

pub use artifact::{ArtifactMeta, Manifest};
#[cfg(feature = "xla")]
pub use engine::{lit_f32, lit_scalar_u32, literal_to_vec, Engine, Executable};
