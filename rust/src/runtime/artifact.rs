//! Artifact metadata: parses the line-oriented `<name>.meta.txt` and
//! `manifest.txt` sidecars written by `python -m compile.aot`.
//!
//! The format is deliberately trivial (`key value` lines) because no
//! serde/JSON crates exist offline — and because the metadata *is* the
//! ABI: parameter order here must match the flatten order the jax export
//! used, or execution scrambles tensors. `python/tests/test_export.py`
//! asserts the Python side; `rust/tests/` asserts this side.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Metadata for one exported model config.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub dataset: String,
    pub model: String, // "supportnet" | "keynet"
    pub d: usize,
    pub c: usize,
    pub h: usize,
    pub layers: usize,
    pub nx: usize,
    pub residual: bool,
    pub homogenize: bool,
    pub alpha: f32,
    pub beta: f32,
    pub size: String,
    pub rho: f32,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub timing_batch: usize,
    pub n_params: usize,
    pub n_param_tensors: usize,
    pub n_state_tensors: usize,
    pub fwd_flops: u64,
    pub grad_flops: u64,
    /// (name, shape) in exact ABI order.
    pub params: Vec<(String, Vec<usize>)>,
}

impl ArtifactMeta {
    pub fn load(path: &Path) -> Result<ArtifactMeta> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<ArtifactMeta> {
        let mut kv: HashMap<&str, &str> = HashMap::new();
        let mut params = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (key, val) = line
                .split_once(' ')
                .ok_or_else(|| anyhow!("bad meta line: {line}"))?;
            if key == "param" {
                let (pname, shape) = val
                    .split_once(' ')
                    .ok_or_else(|| anyhow!("bad param line: {line}"))?;
                let dims: Vec<usize> = if shape == "-" {
                    vec![]
                } else {
                    shape
                        .split(',')
                        .map(|t| t.parse().map_err(|e| anyhow!("bad dim {t}: {e}")))
                        .collect::<Result<_>>()?
                };
                params.push((pname.to_string(), dims));
            } else {
                kv.insert(key, val);
            }
        }
        let get = |k: &str| -> Result<&str> {
            kv.get(k).copied().ok_or_else(|| anyhow!("missing key {k}"))
        };
        let gi = |k: &str| -> Result<usize> { Ok(get(k)?.parse()?) };
        let gf = |k: &str| -> Result<f32> { Ok(get(k)?.parse()?) };
        let meta = ArtifactMeta {
            name: get("name")?.to_string(),
            dataset: get("dataset")?.to_string(),
            model: get("model")?.to_string(),
            d: gi("d")?,
            c: gi("c")?,
            h: gi("h")?,
            layers: gi("layers")?,
            nx: gi("nx")?,
            residual: gi("residual")? != 0,
            homogenize: gi("homogenize")? != 0,
            alpha: gf("alpha")?,
            beta: gf("beta")?,
            size: get("size")?.to_string(),
            rho: gf("rho")?,
            train_batch: gi("train_batch")?,
            eval_batch: gi("eval_batch")?,
            timing_batch: gi("timing_batch")?,
            n_params: gi("n_params")?,
            n_param_tensors: gi("n_param_tensors")?,
            n_state_tensors: gi("n_state_tensors")?,
            fwd_flops: get("fwd_flops")?.parse()?,
            grad_flops: get("grad_flops")?.parse()?,
            params,
        };
        if meta.params.len() != meta.n_param_tensors {
            bail!(
                "{}: param list {} != n_param_tensors {}",
                meta.name,
                meta.params.len(),
                meta.n_param_tensors
            );
        }
        if meta.n_state_tensors != 4 * meta.n_param_tensors + 1 {
            bail!("{}: state ABI mismatch", meta.name);
        }
        Ok(meta)
    }

    /// Total f32 elements across all param tensors.
    pub fn param_elems(&self) -> usize {
        self.params
            .iter()
            .map(|(_, s)| s.iter().product::<usize>().max(1))
            .sum()
    }
}

/// Dataset spec parsed from manifest.txt (mirrors python manifest).
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: String,
    pub n: usize,
    pub d: usize,
    pub n_queries: usize,
    pub shift: f32,
    pub spread: f32,
    pub modes: usize,
    pub seed: u64,
}

impl DatasetSpec {
    pub fn to_corpus_spec(&self) -> crate::data::CorpusSpec {
        crate::data::CorpusSpec {
            name: self.name.clone(),
            n_keys: self.n,
            d: self.d,
            n_queries: self.n_queries,
            shift: self.shift,
            spread: self.spread,
            modes: self.modes,
            seed: self.seed,
        }
    }
}

/// Top-level manifest: datasets + exported config names.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub timing_batch: usize,
    pub aug_sigma: f32,
    pub val_queries: usize,
    pub datasets: Vec<DatasetSpec>,
    pub configs: Vec<String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let mut m = Manifest {
            dir: dir.to_path_buf(),
            train_batch: 256,
            eval_batch: 1024,
            timing_batch: 4096,
            aug_sigma: 0.02,
            val_queries: 1000,
            datasets: Vec::new(),
            configs: Vec::new(),
        };
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let key = it.next().unwrap();
            match key {
                "train_batch" => m.train_batch = it.next().unwrap().parse()?,
                "eval_batch" => m.eval_batch = it.next().unwrap().parse()?,
                "timing_batch" => m.timing_batch = it.next().unwrap().parse()?,
                "aug_sigma" => m.aug_sigma = it.next().unwrap().parse()?,
                "val_queries" => m.val_queries = it.next().unwrap().parse()?,
                "dataset" => {
                    let name = it.next().ok_or_else(|| anyhow!("dataset w/o name"))?;
                    let mut fields: HashMap<&str, &str> = HashMap::new();
                    for tok in it {
                        if let Some((k, v)) = tok.split_once('=') {
                            fields.insert(k, v);
                        }
                    }
                    let g = |k: &str| -> Result<&str> {
                        fields
                            .get(k)
                            .copied()
                            .ok_or_else(|| anyhow!("dataset {name} missing {k}"))
                    };
                    m.datasets.push(DatasetSpec {
                        name: name.to_string(),
                        n: g("n")?.parse()?,
                        d: g("d")?.parse()?,
                        n_queries: g("n_queries")?.parse()?,
                        shift: g("shift")?.parse()?,
                        spread: g("spread")?.parse()?,
                        modes: g("modes")?.parse()?,
                        seed: g("seed")?.parse()?,
                    });
                }
                "config" => {
                    if let Some(name) = it.next() {
                        m.configs.push(name.to_string());
                    }
                }
                _ => {} // forward compatible
            }
        }
        Ok(m)
    }

    pub fn dataset(&self, name: &str) -> Result<&DatasetSpec> {
        self.datasets
            .iter()
            .find(|d| d.name == name)
            .ok_or_else(|| anyhow!("unknown dataset {name}"))
    }

    pub fn meta(&self, config: &str) -> Result<ArtifactMeta> {
        ArtifactMeta::load(&self.dir.join(format!("{config}.meta.txt")))
    }

    /// Config names matching a substring filter.
    pub fn configs_matching(&self, pat: &str) -> Vec<String> {
        self.configs
            .iter()
            .filter(|c| c.contains(pat))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "name t.keynet.xs.l2.c1\ndataset t\nmodel keynet\nd 8\nc 1\nh 16\nlayers 2\nnx 2\ninject 1\nresidual 0\nhomogenize 0\nalpha 0.1\nbeta 20.0\nsize xs\nrho 0.01\ntrain_batch 256\neval_batch 1024\ntiming_batch 0\nn_params 450\nn_param_tensors 6\nn_state_tensors 25\nfwd_flops 1000\ngrad_flops 2000\nparam wx0 8,16\nparam b0 16\nparam wz1 16,16\nparam wx1 8,16\nparam b1 16\nparam wout 16,8\n";

    #[test]
    fn parses_sample_meta() {
        let m = ArtifactMeta::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "t.keynet.xs.l2.c1");
        assert_eq!(m.h, 16);
        assert_eq!(m.params.len(), 6);
        assert_eq!(m.params[0], ("wx0".to_string(), vec![8, 16]));
        assert!(!m.homogenize);
    }

    #[test]
    fn rejects_state_abi_mismatch() {
        let bad = SAMPLE.replace("n_state_tensors 25", "n_state_tensors 24");
        assert!(ArtifactMeta::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_key() {
        let bad = SAMPLE.replace("model keynet\n", "");
        assert!(ArtifactMeta::parse(&bad).is_err());
    }

    #[test]
    fn scalar_param_shape() {
        let txt = SAMPLE.replace("param wout 16,8", "param wout -");
        let m = ArtifactMeta::parse(&txt).unwrap();
        assert_eq!(m.params[5].1, Vec::<usize>::new());
    }
}
