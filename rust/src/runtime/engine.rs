//! PJRT engine: compile-once cache of HLO-text artifacts on the CPU
//! client, plus the Literal conversion helpers used everywhere.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`); see
//! DESIGN.md §2 for why serialized protos are rejected by this XLA build.
//!
//! `Engine` is intentionally `!Send`: PJRT handles are raw pointers. The
//! serving coordinator confines one `Engine` to a dedicated model-runner
//! thread and communicates over channels (coordinator/server.rs), which
//! is also the right serving architecture (single compiled-executable
//! owner, batched execution).

use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use crate::tensor::Tensor;

/// A compiled artifact ready to execute.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with Literal inputs; returns the decomposed output tuple.
    ///
    /// Every artifact is lowered with `return_tuple=True`, so the single
    /// result buffer is a tuple literal we split into its leaves.
    pub fn run(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        Ok(lit.to_tuple()?)
    }
}

/// Compile cache over an artifacts directory.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Engine {
    /// Create a CPU PJRT client rooted at `dir` (the artifacts directory).
    pub fn new(dir: PathBuf) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            dir,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Default engine over [`crate::artifacts_dir`].
    pub fn default_dir() -> Result<Engine> {
        Self::new(crate::artifacts_dir())
    }

    pub fn dir(&self) -> &PathBuf {
        &self.dir
    }

    /// Load + compile `<artifact>.hlo.txt` (cached).
    pub fn load(&self, artifact: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(artifact) {
            return Ok(e.clone());
        }
        let path = self.dir.join(format!("{artifact}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {artifact}"))?;
        let e = Rc::new(Executable {
            name: artifact.to_string(),
            exe,
        });
        self.cache.borrow_mut().insert(artifact.to_string(), e.clone());
        Ok(e)
    }

    /// Number of compiled executables held in cache.
    pub fn cached(&self) -> usize {
        self.cache.borrow().len()
    }
}

// ---------------------------------------------------------------------------
// Literal helpers
// ---------------------------------------------------------------------------

/// Build an f32 literal from a shape + slice.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    anyhow::ensure!(n == data.len(), "shape {shape:?} vs len {}", data.len());
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        shape,
        bytes,
    )?)
}

/// Scalar u32 literal (init seeds).
pub fn lit_scalar_u32(v: u32) -> Result<xla::Literal> {
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::U32,
        &[],
        &v.to_le_bytes(),
    )?)
}

/// Copy a literal's f32 payload out.
pub fn literal_to_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Tensor -> Literal.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    lit_f32(t.shape(), t.data())
}

/// Literal -> Tensor with a caller-supplied shape (literals round-trip
/// shape via meta, which the caller owns).
pub fn literal_to_tensor(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
    let v = literal_to_vec(lit)?;
    Ok(Tensor::from_vec(shape, v))
}
